"""Teacher transport: the ``stream.Teacher`` protocol over a real TCP socket.

``LatencyTeacher`` models the teacher round-trip in *ticks*; this module
replaces the model with an actual network hop so the streaming runtime and
the multiplexer can be exercised against a real transport: a label server
on the other end of a socket, wall-clock latency, and a timeout → loss
mapping (a reply that misses the deadline is treated exactly like a lost
ticket — the runtime's ring entry drains as ``queries_lost``, and a
straggler reply that limps in after its timeout is discarded, never
applied).

Two clients share the transport:

* ``RpcTeacher`` — one connection per tenant, one wire message per ask
  (the PR-3 shape).
* ``BatchedRpcClient`` / ``BatchedRpcTeacher`` — **one connection per
  teacher host shared by every tenant**: asks from all tenants that land
  within a flush window (``batch_window_s``, capped at ``batch_max`` asks)
  are coalesced into a single framed request, and the batched reply is
  demuxed back to per-tenant inboxes.  Each tenant handle still speaks the
  unchanged ``stream.Teacher`` protocol (ask/poll/in_flight, deadlines
  judged at reply *arrival*, timeout → loss), so a ``StreamSession`` can't
  tell the transports apart — only the wire can (see
  ``benchmarks/rpc_bench.py``).  The HMAC handshake runs once per
  connection, i.e. once per host instead of once per tenant.

Wire protocol — two framings, the server answers both, each request in
its own format:

* **v1 (legacy)**: newline-delimited JSON, one object per line, float
  lists for features::

    request:  {"ticket": int, "tick": int, "mask": [bool, ...],
               "feats": [[f, ...], ...]}
    reply:    {"ticket": int, "labels": [int, ...], "answered": [bool, ...]}

* **v2 (default)**: length-prefixed binary frames.  Every frame is::

    [1 byte version = 0x02] [4 bytes LE header length] [JSON header]
    [raw payload]

  The header carries ``{"kind": "ask"|"reply", "payload_len": int, ...}``
  plus per-message specs; the payload is the concatenation, in spec
  order, of raw little-endian numpy buffers — for an ask
  ``mask`` (S × uint8) then ``feats`` (S·n_in × float32), for a reply
  ``answered`` (S × uint8) then ``labels`` (S × int32).  One frame can
  carry many asks (the batched client) or exactly one (``RpcTeacher``
  with ``wire="v2"``); the reply frame mirrors the request frame.  The
  version byte 0x02 can never begin a JSON line, so a server (or reader)
  distinguishes the formats from the first byte of each message.

* **zlib envelope (optional, ``compress=True`` / ``--teacher-compress``)**:
  ``[0x03] [4 bytes LE compressed length] [zlib stream]`` whose
  decompressed bytes are one complete v2 frame.  The framing layer
  unwraps it transparently; the server answers a compressed request with
  a compressed reply (in kind) and meters the win
  (``frames_compressed``, ``compressed_bytes_in/out`` vs
  ``raw_bytes_in/out``).  With a secret, the grant is negotiated in the
  HMAC handshake (``"compress": "zlib"`` on the auth line, echoed in
  ``auth_ok``) so an older server is never sent a byte it can't parse.

Authentication (``secret=...`` / ``--secret``): a *mutual* shared-secret
HMAC challenge–response on connect, always in newline-JSON (it precedes
any framed traffic).  The server opens every connection with
``{"challenge": <hex nonce>}``; the client answers
``{"auth": HMAC_SHA256(secret, challenge), "nonce": <hex nonce>}``; the
server verifies the digest and answers the client's nonce with
``{"auth_ok": HMAC_SHA256(secret, nonce)}`` before any label traffic.  A
wrong or missing digest closes the socket (an unauthenticated client
never receives a label), and a server that cannot answer the client's
nonce — an imposter that merely emits a challenge — is rejected by the
client before any of its labels can train the fleet.  Without a secret
the handshake is skipped entirely (backwards compatible).

The bundled ``LabelServer`` answers deterministically —
``label[s] = (7 * tick + s) % n_out`` — so round-trip tests can assert
exact labels; ``loss_prob`` / ``jitter_s`` / ``delay_s`` fault-inject the
reply path (a lost ask is simply never answered — the client's deadline
maps it to loss).  A real deployment would put the pod-side backbone
ensemble behind the same message shapes.  Run it standalone::

    PYTHONPATH=src python -m repro.engine.rpc --port 0 --n-out 6

(``--port 0`` binds an ephemeral port and prints ``PORT <p>`` on stdout —
that is what ``loopback_server`` parses), or self-test the full
client/server round trip in one process pair::

    PYTHONPATH=src python -m repro.engine.rpc --selftest
"""

from __future__ import annotations

import argparse
import contextlib
import hmac
import json
import os
import pathlib
import secrets as secrets_mod
import socket
import subprocess
import sys
import threading
import time
import zlib
from typing import Optional

import numpy as np

from repro.engine.stream import TeacherReply
from repro.runtime import lockdebug
from repro.runtime import telemetry as _telemetry

# First byte of every v2 frame.  0x02 (STX) can never start a JSON line,
# so the two wire formats coexist on one connection.
WIRE_V2 = 0x02
_WIRE_V2_BYTE = bytes([WIRE_V2])

# Compressed envelope: [0x03][4 bytes LE compressed length][zlib stream]
# where the decompressed bytes are one complete v2 frame.  ``_iter_wire``
# unwraps it transparently, so everything downstream of the framing layer
# (codec, server, reader threads) sees plain v2 messages.  Negotiated in
# the HMAC handshake when a secret is set (``"compress": "zlib"`` in the
# client's auth line, echoed in ``auth_ok``); without a secret a client
# configured with ``compress=True`` just sends envelopes and the server
# answers each compressed request in kind.
WIRE_V3_ZLIB = 0x03
_WIRE_V3_BYTE = bytes([WIRE_V3_ZLIB])

# Speed over ratio: the payloads are float32 feature blocks produced at
# tick rate, so the codec sits on the hot path of every ask.
ZLIB_LEVEL = 1

WIRE_FORMATS = ("v1", "v2")

# Batched-client defaults: how long the first queued ask waits for
# company before the frame is flushed, and the per-frame ask cap.
DEFAULT_BATCH_WINDOW_S = 1e-3
DEFAULT_BATCH_MAX = 64


def expected_label(tick: int, s: int, n_out: int) -> int:
    """The deterministic rule ``LabelServer`` answers with."""
    return (7 * tick + s) % n_out


def _digest(secret: str, challenge: str) -> str:
    return hmac.new(
        secret.encode(), challenge.encode(), "sha256"
    ).hexdigest()


def _shutdown_socket(sock: socket.socket) -> None:
    """Tear a connection down for real: ``close()`` alone only drops one
    reference — ``makefile()`` readers keep the fd (and thus the peer's
    blocking ``recv``) alive, which is exactly how the label server used
    to accumulate one live thread per past connection.  ``shutdown`` sends
    the FIN regardless of refcounts, unblocking both ends' readers."""
    with contextlib.suppress(OSError):
        sock.shutdown(socket.SHUT_RDWR)
    with contextlib.suppress(OSError):
        sock.close()


# ---------------------------------------------------------------------------
# v2 framing codec
# ---------------------------------------------------------------------------


def _encode_frame(header: dict, payload: bytes = b"") -> bytes:
    hdr = json.dumps(header, separators=(",", ":")).encode()
    return _WIRE_V2_BYTE + len(hdr).to_bytes(4, "little") + hdr + payload


def _compress_frame(frame: bytes) -> bytes:
    """Wrap one complete v2 frame in a zlib envelope (wire byte 0x03)."""
    z = zlib.compress(frame, ZLIB_LEVEL)
    return _WIRE_V3_BYTE + len(z).to_bytes(4, "little") + z


def _read_exact(f, n: int) -> bytes:
    buf = f.read(n)
    if buf is None or len(buf) != n:
        raise EOFError(f"stream ended inside a frame (wanted {n} bytes, "
                       f"got {0 if buf is None else len(buf)})")
    return buf


def _iter_wire(f):
    """Yield every message on a buffered binary stream, either format.

    Yields ``("v2", header, payload)`` for binary frames and
    ``("v1", obj_or_None, raw_line)`` for JSON lines (``None`` when the
    line does not parse).  A zlib envelope (0x03) is unwrapped here and
    yielded as the v2 frame it contains, with ``header["_z"] =
    (wire_bytes, raw_bytes)`` so the server can meter compression and
    answer in kind.  Ends cleanly on EOF *between* messages; raises
    ``EOFError`` (or ``ValueError`` for a corrupt header / envelope) when
    the stream dies *inside* a frame — a torn frame desynchronizes
    everything after it, so the caller must drop the connection.
    """
    while True:
        b = f.read(1)
        if not b:
            return
        if b[0] == WIRE_V3_ZLIB:
            zlen = int.from_bytes(_read_exact(f, 4), "little")
            try:
                inner = zlib.decompress(_read_exact(f, zlen))
            except zlib.error as e:
                raise ValueError(f"corrupt zlib envelope: {e}") from e
            if not inner or inner[0] != WIRE_V2:
                raise ValueError("zlib envelope does not contain a v2 frame")
            hlen = int.from_bytes(inner[1:5], "little")
            header = json.loads(inner[5 : 5 + hlen].decode())
            if not isinstance(header, dict):
                raise ValueError(f"v2 frame header is not an object: {header!r}")
            payload = inner[5 + hlen :]
            if len(payload) != int(header.get("payload_len", 0)):
                raise ValueError("zlib envelope payload length mismatch")
            header["_z"] = (5 + zlen, len(inner))
            yield "v2", header, payload
        elif b[0] == WIRE_V2:
            hlen = int.from_bytes(_read_exact(f, 4), "little")
            header = json.loads(_read_exact(f, hlen).decode())
            if not isinstance(header, dict):
                # Valid JSON but not an object: without payload_len the
                # frame boundary is unknowable — corrupt, same as a torn
                # frame (ValueError routes it to the callers' drop paths).
                raise ValueError(f"v2 frame header is not an object: {header!r}")
            payload = _read_exact(f, int(header.get("payload_len", 0)))
            yield "v2", header, payload
        else:
            line = b + f.readline()
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                obj = None
            yield "v1", obj, line


def encode_asks(asks) -> bytes:
    """One v2 request frame from ``[(ticket, tick, mask, feats), ...]``.

    Header spec per ask: ``t`` ticket, ``k`` tick, ``s`` streams, ``d``
    n_in; payload per ask: mask (S × uint8) then feats (S·d × float32 LE).
    """
    specs, chunks = [], []
    for ticket, tick, mask, feats in asks:
        mask8 = np.ascontiguousarray(np.asarray(mask), dtype=np.uint8)
        f32 = np.ascontiguousarray(np.asarray(feats), dtype="<f4")
        s = int(mask8.shape[0])
        specs.append({"t": int(ticket), "k": int(tick), "s": s,
                      "d": int(f32.size // s) if s else 0})
        chunks += [mask8.tobytes(), f32.tobytes()]
    payload = b"".join(chunks)
    return _encode_frame(
        {"kind": "ask", "payload_len": len(payload), "asks": specs}, payload
    )


def decode_asks(header: dict, payload: bytes):
    """Inverse of ``encode_asks`` → ``[(ticket, tick, mask, feats), ...]``."""
    out, off = [], 0
    for spec in header["asks"]:
        s, d = int(spec["s"]), int(spec["d"])
        mask = np.frombuffer(payload, np.uint8, s, off).astype(bool)
        off += s
        feats = np.frombuffer(payload, "<f4", s * d, off).reshape(s, d)
        off += s * d * 4
        out.append((int(spec["t"]), int(spec["k"]), mask, feats))
    return out


def encode_replies(replies) -> bytes:
    """One v2 reply frame from ``[(ticket, answered, labels), ...]``."""
    specs, chunks = [], []
    for ticket, answered, labels in replies:
        a8 = np.ascontiguousarray(np.asarray(answered), dtype=np.uint8)
        l32 = np.ascontiguousarray(np.asarray(labels), dtype="<i4")
        specs.append({"t": int(ticket), "s": int(a8.shape[0])})
        chunks += [a8.tobytes(), l32.tobytes()]
    payload = b"".join(chunks)
    return _encode_frame(
        {"kind": "reply", "payload_len": len(payload), "replies": specs},
        payload,
    )


def decode_replies(header: dict, payload: bytes) -> list[TeacherReply]:
    """Inverse of ``encode_replies`` → ``[TeacherReply, ...]``."""
    out, off = [], 0
    for spec in header["replies"]:
        s = int(spec["s"])
        answered = np.frombuffer(payload, np.uint8, s, off).astype(bool)
        off += s
        labels = np.frombuffer(payload, "<i4", s, off).astype(np.int32)
        off += s * 4
        out.append(TeacherReply(ticket=int(spec["t"]), labels=labels,
                                answered=answered))
    return out


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


class LabelServer:
    """Threaded loopback label server (one thread per client connection).

    Answers both wire formats, each request in its own format: a v1 JSON
    line gets a v1 JSON line back, a v2 frame (single or batched) gets one
    v2 reply frame covering every ask it carried.  ``loss_prob`` drops
    individual asks from the reply (the client's deadline maps them to
    loss), ``delay_s`` + uniform ``jitter_s`` sleep before each reply —
    the fault model the accounting identity is exercised against.
    """

    def __init__(self, port: int = 0, n_out: int = 6, delay_s: float = 0.0,
                 host: str = "127.0.0.1", secret: Optional[str] = None,
                 loss_prob: float = 0.0, jitter_s: float = 0.0,
                 seed: int = 0):
        self.n_out = n_out
        self.delay_s = delay_s
        self.jitter_s = jitter_s
        self.loss_prob = loss_prob
        self.seed = seed
        self.secret = secret
        self.auth_failures = 0  # connections rejected by the HMAC handshake
        self.requests_v1 = 0  # v1 JSON-line requests served
        self.frames_v2 = 0  # v2 request frames served (1 frame : N asks)
        self.asks_served = 0  # individual asks across both formats
        self.frame_errors = 0  # undecodable lines / torn v2 frames
        # Compression metering (zlib envelopes, both directions): wire
        # bytes actually moved vs the raw v2 bytes they stand for — the
        # transport-compression win is raw/compressed.
        self.frames_compressed = 0  # compressed request frames served
        self.compressed_bytes_in = 0  # wire bytes of compressed requests
        self.raw_bytes_in = 0  # their decompressed v2 sizes
        self.compressed_bytes_out = 0  # wire bytes of compressed replies
        self.raw_bytes_out = 0  # their raw v2 sizes
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(8)
        self.host = host
        self.port = self._sock.getsockname()[1]
        self._stop = threading.Event()
        # Guards the thread/conn bookkeeping AND the public counters —
        # concurrent per-connection threads must not lose increments
        # (tests assert exact counts).
        self._tlock = lockdebug.make_lock("rpc.LabelServer._tlock")
        self._threads: list[threading.Thread] = []
        self._conns: set[socket.socket] = set()
        self._accepted = 0

    def serve_forever(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                break
            if self._stop.is_set():  # close()'s wake-up dial, not a client
                with contextlib.suppress(OSError):
                    conn.close()
                break
            with self._tlock:
                # A long-running server accepts unboundedly many
                # connections; dead client threads must not accumulate.
                self._threads = [t for t in self._threads if t.is_alive()]
                self._accepted += 1
                self._conns.add(conn)
                t = threading.Thread(
                    target=self._client, args=(conn, self._accepted),
                    daemon=True,
                )
                t.start()
                self._threads.append(t)

    def start(self) -> "LabelServer":
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        with self._tlock:
            self._threads.append(t)
        return self

    def thread_count(self) -> int:
        """Live worker threads (accept loop + open connections)."""
        with self._tlock:
            return sum(t.is_alive() for t in self._threads)

    def stats(self) -> dict:
        """Every public counter as one JSON-able dict — the payload a wire
        ``stats`` request returns (see ``server_stats``).  The server
        usually runs as a separate process, so this wire scrape is the
        only way a client-side report can see these numbers."""
        with self._tlock:
            out = {
                "auth_failures": self.auth_failures,
                "requests_v1": self.requests_v1,
                "frames_v2": self.frames_v2,
                "asks_served": self.asks_served,
                "frame_errors": self.frame_errors,
                "frames_compressed": self.frames_compressed,
                "compressed_bytes_in": self.compressed_bytes_in,
                "raw_bytes_in": self.raw_bytes_in,
                "compressed_bytes_out": self.compressed_bytes_out,
                "raw_bytes_out": self.raw_bytes_out,
                "connections_accepted": self._accepted,
            }
        out["thread_count"] = self.thread_count()
        out["n_out"] = self.n_out
        out["delay_s"] = self.delay_s
        out["jitter_s"] = self.jitter_s
        out["loss_prob"] = self.loss_prob
        return out

    def close(self) -> None:
        """Stop accepting, unblock and join every client thread."""
        self._stop.set()
        # Closing a listening socket does not reliably interrupt a thread
        # blocked in accept(); dial it once so the accept loop wakes, sees
        # the stop flag, and exits.
        dial_host = "127.0.0.1" if self.host in ("0.0.0.0", "::") else self.host
        with contextlib.suppress(OSError):
            socket.create_connection((dial_host, self.port), timeout=0.5).close()
        with contextlib.suppress(OSError):
            self._sock.close()
        with self._tlock:
            conns = list(self._conns)
            threads = list(self._threads)
        for c in conns:
            _shutdown_socket(c)
        me = threading.current_thread()
        for t in threads:
            if t is not me:
                t.join(timeout=5.0)
        with self._tlock:
            self._threads = [t for t in self._threads if t.is_alive()]

    def _client(self, conn: socket.socket, conn_id: int) -> None:
        # Per-connection fault rng: deterministic given (seed, conn_id),
        # unshared so concurrent connections never race it.
        rng = np.random.default_rng((self.seed, conn_id))
        try:
            with conn, conn.makefile("rwb") as f:
                if self.secret is not None and not self._handshake(f):
                    self._count("auth_failures")
                    return  # close unauthenticated connections: no labels
                self._serve_connection(f, rng)
        finally:
            with self._tlock:
                self._conns.discard(conn)

    def _count(self, counter: str, by: int = 1) -> None:
        with self._tlock:
            setattr(self, counter, getattr(self, counter) + by)

    def _serve_connection(self, f, rng) -> None:
        try:
            for kind, obj, payload in _iter_wire(f):
                if kind == "v2":
                    if isinstance(obj, dict) and obj.get("kind") == "stats":
                        # Live counter scrape: answered immediately (no
                        # fault-model sleep — operators scrape a server
                        # that is deliberately simulating slow labels).
                        reply = _encode_frame(
                            {"kind": "stats", "payload_len": 0,
                             "stats": self.stats()}, b"")
                        try:
                            f.write(reply)
                            f.flush()
                        except OSError:
                            return
                        continue
                    if not isinstance(obj, dict) or obj.get("kind") != "ask":
                        continue
                    z = obj.pop("_z", None)
                    try:
                        asks = decode_asks(obj, payload)
                    except (KeyError, TypeError, ValueError):
                        self._count("frame_errors")
                        return  # desynchronized: drop the connection
                    self._count("frames_v2")
                    out = encode_replies(
                        (t, mask, labels)
                        for t, mask, labels in self._answer(asks, rng)
                    )
                    if z is not None:
                        # Answer a compressed request in kind and meter
                        # both directions of the compression win.
                        self._count("frames_compressed")
                        self._count("compressed_bytes_in", by=z[0])
                        self._count("raw_bytes_in", by=z[1])
                        raw_len = len(out)
                        out = _compress_frame(out)
                        self._count("compressed_bytes_out", by=len(out))
                        self._count("raw_bytes_out", by=raw_len)
                else:
                    if obj is None or not isinstance(obj, dict):
                        self._count("frame_errors")
                        continue
                    self._count("requests_v1")
                    ask = (
                        int(obj.get("ticket", 0)),
                        int(obj.get("tick", 0)),
                        np.asarray(obj.get("mask", []), bool),
                        None,
                    )
                    replies = self._answer([ask], rng)
                    if not replies:
                        continue  # lost: never answered
                    ticket, answered, labels = replies[0]
                    out = (json.dumps({
                        "ticket": ticket,
                        "labels": [int(v) for v in labels],
                        "answered": [bool(v) for v in answered],
                    }) + "\n").encode()
                self._sleep(rng)
                try:
                    f.write(out)
                    f.flush()
                except OSError:
                    return
        except (EOFError, ValueError):
            # Stream died (or header corrupted) inside a frame.
            self._count("frame_errors")

    def _answer(self, asks, rng):
        """Labels for each surviving ask: ``[(ticket, answered, labels)]``
        (a ``loss_prob`` casualty simply has no entry — never answered)."""
        out = []
        self._count("asks_served", by=len(asks))
        for ticket, tick, mask, _feats in asks:
            if self.loss_prob > 0.0 and rng.uniform() < self.loss_prob:
                continue
            labels = np.asarray(
                [expected_label(tick, s, self.n_out) for s in range(len(mask))],
                np.int32,
            )
            out.append((ticket, np.asarray(mask, bool), labels))
        return out

    def _sleep(self, rng) -> None:
        delay = self.delay_s
        if self.jitter_s > 0.0:
            delay += float(rng.uniform(0.0, self.jitter_s))
        if delay > 0.0:
            time.sleep(delay)

    def _handshake(self, f) -> bool:
        """Mutual challenge–response: send a nonce, require its keyed digest
        back (constant-time compare), then prove *our* knowledge of the
        secret by answering the client's nonce — all before serving a
        single label."""
        challenge = secrets_mod.token_hex(16)
        try:
            f.write((json.dumps({"challenge": challenge}) + "\n").encode())
            f.flush()
            line = f.readline()
        except OSError:
            return False
        try:
            reply = json.loads(line)
        except ValueError:
            # Not JSON — including a BINARY v2 frame from a no-secret
            # client that skipped straight to asking (UnicodeDecodeError
            # is a ValueError too): an unauthenticated connection.
            return False
        if not isinstance(reply, dict):
            return False
        if not hmac.compare_digest(
            str(reply.get("auth", "")), _digest(self.secret, challenge)
        ):
            return False
        ok = {"auth_ok": _digest(self.secret, str(reply.get("nonce", "")))}
        if reply.get("compress") == "zlib":
            # Compression negotiation rides the handshake: echo the
            # client's request so it knows zlib envelopes are understood.
            ok["compress"] = "zlib"
        try:
            f.write((json.dumps(ok) + "\n").encode())
            f.flush()
        except OSError:
            return False
        return True


# ---------------------------------------------------------------------------
# Client-side connection plumbing (shared by both clients)
# ---------------------------------------------------------------------------


def _authenticate(sock: socket.socket, wfile, secret: str,
                  compress: bool = False) -> bool:
    """Client half of the mutual HMAC handshake (see module docstring).
    Raises ``ConnectionError`` (after closing the socket) unless BOTH ends
    prove knowledge of the secret.  ``compress=True`` rides a
    ``"compress": "zlib"`` request on the auth line; the return value is
    whether the server echoed the grant (older servers simply don't)."""
    with sock.makefile("rb") as rf:
        try:
            hello = json.loads(rf.readline())
        except (OSError, ValueError):
            hello = None  # silent/closed/garbled server: not authenticated
        if not isinstance(hello, dict) or "challenge" not in hello:
            _shutdown_socket(sock)
            raise ConnectionError(
                "label server sent no auth challenge but a "
                "--teacher-secret is configured; refusing the "
                "unauthenticated connection"
            )
        nonce = secrets_mod.token_hex(16)
        auth_line = {
            "auth": _digest(secret, hello["challenge"]),
            "nonce": nonce,
        }
        if compress:
            auth_line["compress"] = "zlib"
        wfile.write((json.dumps(auth_line) + "\n").encode())
        wfile.flush()
        try:
            proof = json.loads(rf.readline())
        except (OSError, ValueError):
            proof = None
    ok = isinstance(proof, dict) and hmac.compare_digest(
        str(proof.get("auth_ok", "")), _digest(secret, nonce)
    )
    if not ok:
        _shutdown_socket(sock)
        raise ConnectionError(
            "label server failed to prove knowledge of the shared "
            "secret; refusing to train on its labels"
        )
    return bool(compress and proof.get("compress") == "zlib")


class _WireConnection:
    """The client-side connection plumbing both teachers share: dial +
    handshake, a buffered writer behind a write lock (two threads sharing
    a connection must never interleave partial frames), wire counters,
    and poison-on-failure — a write that raises ``OSError`` mid-frame
    leaves the stream desynchronized for the server, so the connection is
    marked ``broken`` and every later send skips the wire entirely
    (the callers map the silence to timeout → loss)."""

    def __init__(self, host: str, port: int, connect_timeout_s: float,
                 secret: Optional[str], compress: bool = False):
        self.sock = socket.create_connection((host, port),
                                             timeout=connect_timeout_s)
        self.wfile = self.sock.makefile("wb")
        # With a handshake, compression is negotiated (an older server
        # that doesn't echo the grant never sees a 0x03 byte); without
        # one there is no negotiation channel, so the caller's request is
        # taken at face value — the server answers envelopes in kind.
        if secret is not None:
            self.compress_granted = _authenticate(
                self.sock, self.wfile, secret, compress=compress)
        else:
            self.compress_granted = bool(compress)
        # connect_timeout_s governed the dial (and the auth readline);
        # steady-state reads must block indefinitely — reply deadlines are
        # enforced per ticket, not by a socket idle timeout.
        self.sock.settimeout(None)
        self.wlock = lockdebug.make_lock("rpc._WireConnection.wlock")
        self.broken = False
        self.messages = 0  # request messages actually written
        self.bytes = 0  # request bytes actually written

    def send(self, data: bytes) -> bool:
        """Write one whole frame/line; False when the connection is (or
        just became) dead — never writes after a half-frame poisoned it."""
        with self.wlock:
            if self.broken:
                return False
            try:
                self.wfile.write(data)
                self.wfile.flush()
            except OSError:
                self.broken = True
                _shutdown_socket(self.sock)
                return False
            self.messages += 1
            self.bytes += len(data)
            return True

    def close(self) -> None:
        # Shutdown BEFORE touching the write lock: a writer blocked in
        # flush() (peer stopped draining, send buffer full) holds the
        # lock, and only the shutdown can fail its write and free it —
        # lock-then-shutdown would deadlock close() against it.
        _shutdown_socket(self.sock)
        with self.wlock:
            with contextlib.suppress(OSError, ValueError):
                self.wfile.close()


def _reply_reader(sock: socket.socket, handler) -> None:
    """Reader-thread body both teachers share: decode every wire message
    (either format) and hand reply batches to ``handler(replies,
    arrived)``; exits when the socket dies (mid-frame included)."""
    try:
        with sock.makefile("rb") as f:
            for kind, obj, payload in _iter_wire(f):
                replies = _parse_wire_replies(kind, obj, payload)
                if replies:
                    handler(replies, time.monotonic())
    except (OSError, ValueError, EOFError):
        pass  # socket closed (or stream died mid-frame)


def _parse_wire_replies(kind, obj, payload) -> list[TeacherReply]:
    """Replies carried by one wire message, either format (empty when the
    message is not a reply — e.g. an unexpected auth challenge)."""
    if kind == "v2":
        if isinstance(obj, dict) and obj.get("kind") == "reply":
            return decode_replies(obj, payload)
        return []
    if not isinstance(obj, dict) or "ticket" not in obj:
        return []
    return [TeacherReply(
        ticket=int(obj["ticket"]),
        labels=np.asarray(obj["labels"], np.int32),
        answered=np.asarray(obj["answered"], bool),
    )]


# ---------------------------------------------------------------------------
# Per-tenant client (one connection per tenant)
# ---------------------------------------------------------------------------


class RpcTeacher:
    """``stream.Teacher`` over its own TCP socket, with timeout → loss.

    ``ask`` serializes the tick's features + mask and sends them (one wire
    message per ask — ``wire="v2"`` binary frames by default, ``"v1"``
    newline-JSON for back-compat); a reader thread validates each reply
    against its ticket's deadline *at arrival time* and queues the
    survivors in an inbox that ``poll`` drains — so a reply that made the
    deadline is never lost to a late poll (e.g. a tick stalled behind an
    XLA compile).  A ticket unanswered for ``timeout_s`` wall seconds
    leaves ``in_flight()`` and is mapped to loss: the runtime's pending
    ring entry is never claimed (it drains as ``queries_lost``), and a
    reply that misses its deadline is dropped at arrival (counted in
    ``timed_out``) — never delivered, so a stale straggler cannot train
    the fleet.

    Socket writes are serialized by a write lock (two threads sharing a
    connection must never interleave partial frames), and a write that
    raises ``OSError`` mid-frame marks the connection **dead**: the stream
    past a half-written frame is garbage to the server, so every later ask
    skips the wire entirely and maps straight to timeout → loss instead of
    desynchronizing the framing further.
    """

    def __init__(self, host: str, port: int, timeout_s: float = 5.0,
                 connect_timeout_s: float = 5.0, secret: Optional[str] = None,
                 wire: str = "v2", compress: bool = False):
        if wire not in WIRE_FORMATS:
            raise ValueError(f"unknown wire format {wire!r}; choose {WIRE_FORMATS}")
        if compress and wire != "v2":
            raise ValueError(
                "compress=True requires the v2 wire format (zlib envelopes "
                "carry v2 frames; v1 newline-JSON has no framing to wrap)")
        self.timeout_s = timeout_s
        self.wire = wire
        self._endpoint = f"{host}:{int(port)}"  # telemetry label only
        # Authentication (when configured) happens inside the connection
        # constructor, synchronously, before the reader thread owns the
        # socket.
        self._conn = _WireConnection(host, port, connect_timeout_s, secret,
                                     compress=compress)
        self._lock = lockdebug.make_lock("rpc.RpcTeacher._lock")  # pending map + inbox
        self._next_ticket = 0
        # ticket -> wall deadline; present == still in flight.
        self._pending: dict[int, float] = {}
        self._inbox: list[TeacherReply] = []
        self.timed_out = 0  # tickets whose reply missed (or never made) the deadline
        self._reader = threading.Thread(
            target=_reply_reader, args=(self._conn.sock, self._on_replies),
            daemon=True,
        )
        self._reader.start()

    @property
    def broken(self) -> bool:
        """True once a write failure poisoned the connection (every ask
        since maps to timeout → loss without touching the wire)."""
        return self._conn.broken

    @property
    def wire_messages(self) -> int:
        return self._conn.messages

    @property
    def wire_bytes(self) -> int:
        return self._conn.bytes

    def sync_telemetry(self, **labels) -> None:
        """Mirror wire meters into the enabled telemetry registry (see
        ``BatchedRpcClient.sync_telemetry``); no-op when telemetry is off."""
        tel = _telemetry.TELEMETRY
        if tel is None:
            return
        labels.setdefault("endpoint", self._endpoint)
        reg = tel.registry
        reg.set_counter("odl_rpc_wire_messages", self.wire_messages, **labels)
        reg.set_counter("odl_rpc_wire_bytes", self.wire_bytes, **labels)
        with self._lock:
            reg.set_counter("odl_rpc_timed_out", self.timed_out, **labels)

    def _on_replies(self, replies: list[TeacherReply], arrived: float) -> None:
        with self._lock:
            for reply in replies:
                deadline = self._pending.pop(reply.ticket, None)
                if deadline is None:
                    # Unknown ticket, or already expired (and counted) by
                    # _expire.
                    continue
                if arrived > deadline:
                    self.timed_out += 1  # straggler: timeout -> loss
                    continue
                self._inbox.append(reply)

    def ask(self, feats, mask, tick: int) -> int:
        with self._lock:
            ticket = self._next_ticket
            self._next_ticket += 1
            self._pending[ticket] = time.monotonic() + self.timeout_s
        mask_np = np.asarray(mask, bool)
        if self.wire == "v2":
            data = encode_asks([(ticket, int(tick), mask_np,
                                 np.asarray(feats, np.float32))])
            if self._conn.compress_granted:
                data = _compress_frame(data)
        else:
            data = (json.dumps({
                "ticket": ticket,
                "tick": int(tick),
                "mask": mask_np.tolist(),
                "feats": np.asarray(feats, np.float32).tolist(),
            }) + "\n").encode()
        # A dead connection leaves the ticket pending until its deadline,
        # then maps it to loss like any other timeout.
        self._conn.send(data)
        return ticket

    def _expire(self) -> None:
        now = time.monotonic()
        with self._lock:
            dead = [t for t, dl in self._pending.items() if dl < now]
            for t in dead:
                del self._pending[t]
                self.timed_out += 1

    def poll(self, tick: int) -> list[TeacherReply]:
        self._expire()  # never-arrived tickets past their deadline -> loss
        with self._lock:
            out, self._inbox = self._inbox, []
        return out

    def in_flight(self) -> int:
        self._expire()
        with self._lock:
            return len(self._pending)

    def close(self) -> None:
        self._conn.close()

    def __enter__(self) -> "RpcTeacher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Batched shared-connection client (one connection per teacher host)
# ---------------------------------------------------------------------------


class BatchedRpcTeacher:
    """One tenant's ``stream.Teacher`` handle over a shared
    ``BatchedRpcClient`` — the unchanged protocol (ask/poll/in_flight,
    deadlines judged at arrival, timeout → loss), multiplexed with every
    other tenant's traffic onto one connection.  Create via
    ``BatchedRpcClient.tenant()``."""

    def __init__(self, client: "BatchedRpcClient", name: Optional[str] = None):
        self._client = client
        self.name = name
        self._inbox: list[TeacherReply] = []
        self.timed_out = 0  # this tenant's deadline casualties

    def ask(self, feats, mask, tick: int) -> int:
        return self._client._ask(self, feats, mask, tick)

    def poll(self, tick: int) -> list[TeacherReply]:
        return self._client._poll(self)

    def in_flight(self) -> int:
        return self._client._in_flight(self)

    def close(self) -> None:
        """No-op: the shared connection outlives any one tenant — close
        the ``BatchedRpcClient`` itself when every tenant is done."""

    def __enter__(self) -> "BatchedRpcTeacher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class BatchedRpcClient:
    """One shared connection to one teacher host, multiplexing every
    tenant's asks into batched v2 frames.

    ``tenant()`` mints a per-tenant ``BatchedRpcTeacher`` handle.  An ask
    from any handle is assigned a connection-global ticket, registered
    with its wall deadline, and queued; the queue is flushed as **one**
    framed request when either ``batch_max`` asks have accumulated or
    ``batch_window_s`` has elapsed since the first queued ask (a
    background flusher owns the window; ``batch_window_s=0`` flushes
    inline, degenerating to one frame per ask).  The reader thread demuxes
    each reply to the handle that asked, judging deadlines at arrival —
    semantics are bit-for-bit those of a per-tenant ``RpcTeacher``
    connection (locked by ``tests/test_rpc.py``); only the number of wire
    messages changes (measured by ``benchmarks/rpc_bench.py``).

    The HMAC handshake (``secret=``) runs once, here, per connection —
    not once per tenant.  Writes hold the write lock for the whole frame,
    and a mid-frame ``OSError`` marks the connection dead.  A dead
    connection gets **one** metered lazy reconnect-and-reask attempt at
    the next flush (``reconnects`` / ``asks_reasked``): a fresh dial +
    handshake + reader thread, with every still-pending unexpired ticket
    re-asked through it — original deadlines kept, so a reply that would
    have timed out anyway still maps to loss.  If the dial fails (or the
    fresh connection poisons again before the flush), the old behavior
    applies: queued and later asks map straight to timeout → loss until
    the *next* poisoning earns its own single attempt.
    """

    def __init__(self, host: str, port: int, timeout_s: float = 5.0,
                 connect_timeout_s: float = 5.0, secret: Optional[str] = None,
                 batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
                 batch_max: int = DEFAULT_BATCH_MAX, compress: bool = False):
        if batch_max < 1:
            raise ValueError(f"batch_max must be >= 1, got {batch_max}")
        self.timeout_s = timeout_s
        self.batch_window_s = batch_window_s
        self.batch_max = int(batch_max)
        # Kept for the lazy reconnect path.
        self._host, self._port = host, int(port)
        self._connect_timeout_s = connect_timeout_s
        self._secret = secret
        self._compress = bool(compress)
        # The write lock + HMAC handshake live in the connection — once
        # per connection, i.e. once per teacher host, not once per tenant.
        self._conn = _WireConnection(host, port, connect_timeout_s, secret,
                                     compress=compress)
        self._cond = lockdebug.make_condition("rpc.BatchedRpcClient._cond")  # queue + pending + inboxes
        self._closed = False
        self._next_ticket = 0
        # ticket -> (owning handle, wall deadline, wire payload); present
        # == in flight.  The payload (tick, mask, feats) rides along so a
        # reconnect can re-ask in-flight tickets — bounded by the tenants'
        # ring capacities, same rationale as ``stream.PendingTicket.x``.
        self._pending: dict[
            int, tuple[BatchedRpcTeacher, float, tuple]
        ] = {}
        # Unflushed asks: (ticket, tick, mask, feats).
        self._queue: list[tuple[int, int, np.ndarray, np.ndarray]] = []
        self._flush_deadline: Optional[float] = None
        self._tenants: list[BatchedRpcTeacher] = []
        self._reconnect_lock = lockdebug.make_lock("rpc.BatchedRpcClient._reconnect_lock")
        self._reconnect_spent = False  # current broken conn's attempt used
        self.timed_out = 0  # deadline casualties across all tenants
        self.asks_sent = 0  # individual asks across all frames
        self.reconnects = 0  # successful lazy reconnects
        self.asks_reasked = 0  # in-flight asks re-sent after a reconnect
        self._reader = threading.Thread(
            target=_reply_reader, args=(self._conn.sock, self._on_replies),
            daemon=True,
        )
        self._reader.start()
        self._flusher: Optional[threading.Thread] = None
        if self.batch_window_s > 0:
            self._flusher = threading.Thread(target=self._flush_loop, daemon=True)
            self._flusher.start()

    @property
    def broken(self) -> bool:
        return self._conn.broken

    @property
    def wire_messages(self) -> int:
        return self._conn.messages

    @property
    def wire_bytes(self) -> int:
        return self._conn.bytes

    def sync_telemetry(self, **labels) -> None:
        """Mirror this connection's wire meters into the enabled telemetry
        registry (absolute writes, same pull-based discipline as
        ``StreamSession.sync_telemetry``); no-op when telemetry is off."""
        tel = _telemetry.TELEMETRY
        if tel is None:
            return
        labels.setdefault("endpoint", f"{self._host}:{self._port}")
        reg = tel.registry
        reg.set_counter("odl_rpc_wire_messages", self.wire_messages, **labels)
        reg.set_counter("odl_rpc_wire_bytes", self.wire_bytes, **labels)
        with self._cond:
            reg.set_counter("odl_rpc_asks_sent", self.asks_sent, **labels)
            reg.set_counter("odl_rpc_timed_out", self.timed_out, **labels)
            reg.set_counter("odl_rpc_reconnects", self.reconnects, **labels)
            reg.set_counter("odl_rpc_asks_reasked", self.asks_reasked,
                            **labels)

    def tenant(self, name: Optional[str] = None) -> BatchedRpcTeacher:
        """A new per-tenant ``stream.Teacher`` handle on this connection."""
        handle = BatchedRpcTeacher(self, name=name)
        with self._cond:
            self._tenants.append(handle)
        return handle

    # -- Teacher-protocol backend (called through the handles) -------------

    def _ask(self, handle: BatchedRpcTeacher, feats, mask, tick: int) -> int:
        mask_np = np.asarray(mask, bool)
        feats_np = np.asarray(feats, np.float32)
        batch = None
        with self._cond:
            ticket = self._next_ticket
            self._next_ticket += 1
            self._pending[ticket] = (
                handle, time.monotonic() + self.timeout_s,
                (int(tick), mask_np, feats_np),
            )
            self._queue.append((ticket, int(tick), mask_np, feats_np))
            if (len(self._queue) >= self.batch_max
                    or self.batch_window_s <= 0 or self._conn.broken):
                batch = self._take_locked()
            else:
                if self._flush_deadline is None:
                    self._flush_deadline = time.monotonic() + self.batch_window_s
                self._cond.notify_all()
        if batch:
            self._send(batch)
        return ticket

    def _poll(self, handle: BatchedRpcTeacher) -> list[TeacherReply]:
        self._expire()
        with self._cond:
            out, handle._inbox = handle._inbox, []
        return out

    def _in_flight(self, handle: BatchedRpcTeacher) -> int:
        self._expire()
        with self._cond:
            return sum(1 for ent in self._pending.values() if ent[0] is handle)

    # -- internals ---------------------------------------------------------

    def _take_locked(self):  # odlint: holds-lock(_cond)
        batch = self._queue[: self.batch_max]
        self._queue = self._queue[self.batch_max:]
        self._flush_deadline = (
            time.monotonic() + self.batch_window_s if self._queue else None
        )
        return batch

    def _flush_loop(self) -> None:
        while True:
            with self._cond:
                while not self._closed and self._flush_deadline is None:
                    self._cond.wait()
                if self._closed:
                    return
                now = time.monotonic()
                if self._flush_deadline > now:
                    # Wait out the window (new asks may refill batch_max
                    # and flush inline first — re-check on wake).
                    self._cond.wait(timeout=self._flush_deadline - now)
                    continue
                batch = self._take_locked()
            if batch:
                self._send(batch)

    def _send(self, batch) -> None:
        if self._conn.broken:
            # One lazy reconnect attempt per poisoned connection.  On
            # success every still-pending unexpired ticket — including
            # this batch's, registered in ``_ask`` — is re-asked through
            # the fresh connection, so the batch must not be sent again
            # here.  On failure the old behavior applies: the tickets
            # stay pending until their deadlines, then map to loss.
            self._reconnect_and_reask()
            return
        tel = _telemetry.TELEMETRY
        tok = tel.tracer.begin("rpc.flush") if tel is not None else None
        sent = self._conn.send(self._frame(batch))
        if sent:
            with self._cond:
                self.asks_sent += len(batch)
        if tok is not None:
            tel.tracer.end(tok, asks=len(batch), sent=sent)
            tel.registry.observe("odl_rpc_batch_occupancy", len(batch))

    def _frame(self, batch) -> bytes:
        data = encode_asks(batch)
        # Read the grant off the *current* connection: a reconnect
        # renegotiates, and an older server may refuse what the original
        # connection had granted.
        if self._conn.compress_granted:
            data = _compress_frame(data)
        return data

    def _reconnect_and_reask(self) -> None:
        with self._reconnect_lock:
            if self._closed:
                return  # nobody is left to consume the replies
            if not self._conn.broken:
                return  # another thread already swapped in a live conn
            if self._reconnect_spent:
                return  # this poisoning's single attempt is used up
            self._reconnect_spent = True
            try:
                conn = _WireConnection(self._host, self._port,
                                       self._connect_timeout_s, self._secret,
                                       compress=self._compress)
            except OSError:
                return
            old, self._conn = self._conn, conn
            threading.Thread(
                target=_reply_reader, args=(conn.sock, self._on_replies),
                daemon=True,
            ).start()
            old.close()
            tel = _telemetry.TELEMETRY
            if tel is not None:
                tel.tracer.event("rpc.reconnect",
                                 endpoint=f"{self._host}:{self._port}")
            with self._cond:
                self.reconnects += 1
                # A later poisoning earns its own single attempt.
                self._reconnect_spent = False
                # Every pending ticket's frame either died with the old
                # socket or was answered on it after it went half-dead —
                # either way the reply can now only arrive via a re-ask.
                # Original deadlines are kept: a reply that would have
                # timed out anyway still maps to loss.
                now = time.monotonic()
                resend = [
                    (t, *payload)
                    for t, (_, dl, payload) in sorted(self._pending.items())
                    if dl >= now
                ]
            for i in range(0, len(resend), self.batch_max):
                chunk = resend[i:i + self.batch_max]
                if self._conn.send(self._frame(chunk)):
                    with self._cond:
                        self.asks_sent += len(chunk)
                        self.asks_reasked += len(chunk)

    def _on_replies(self, replies: list[TeacherReply], arrived: float) -> None:
        with self._cond:
            for reply in replies:
                ent = self._pending.pop(reply.ticket, None)
                if ent is None:
                    continue  # unknown or already expired
                handle, deadline = ent[0], ent[1]
                if arrived > deadline:
                    handle.timed_out += 1
                    self.timed_out += 1
                    continue
                handle._inbox.append(reply)

    def _expire(self) -> None:
        now = time.monotonic()
        with self._cond:
            dead = [t for t, ent in self._pending.items() if ent[1] < now]
            for t in dead:
                handle = self._pending.pop(t)[0]
                handle.timed_out += 1
                self.timed_out += 1

    def close(self) -> None:
        with self._cond:
            self._closed = True
            batch = self._take_locked() if self._queue else None
            self._cond.notify_all()
        if batch:
            self._send(batch)  # best effort: don't strand queued asks
        if self._flusher is not None:
            self._flusher.join(timeout=2.0)
        self._conn.close()

    def __enter__(self) -> "BatchedRpcClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def server_stats(host: str, port: int, secret: Optional[str] = None,
                 timeout_s: float = 5.0) -> dict:
    """Scrape a running ``LabelServer``'s counters over the wire.

    Dials a fresh connection, performs the HMAC handshake when a secret is
    configured, sends one v2 ``{"kind": "stats"}`` frame, and returns the
    server's counter dict (see ``LabelServer.stats``).  The label server
    usually lives in another process, so this is the only way a client-side
    report can include its numbers.
    """
    sock = socket.create_connection((host, port), timeout=timeout_s)
    try:
        wfile = sock.makefile("wb")
        if secret is not None:
            _authenticate(sock, wfile, secret)
        wfile.write(_encode_frame({"kind": "stats", "payload_len": 0}, b""))
        wfile.flush()
        with sock.makefile("rb") as rf:
            for kind, obj, _payload in _iter_wire(rf):
                if (kind == "v2" and isinstance(obj, dict)
                        and obj.get("kind") == "stats"):
                    return dict(obj.get("stats") or {})
    finally:
        _shutdown_socket(sock)
    raise ConnectionError(
        "label server closed the connection without answering the stats "
        "request (pre-stats server version?)")


# ---------------------------------------------------------------------------
# Loopback subprocess helper
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def loopback_server(n_out: int = 6, delay_s: float = 0.0,
                    secret: Optional[str] = None, loss_prob: float = 0.0,
                    jitter_s: float = 0.0):
    """Spawn ``python -m repro.engine.rpc`` as a subprocess label server on
    an ephemeral loopback port; yields ``(host, port)`` and tears the
    process down on exit."""
    src_root = str(pathlib.Path(__file__).resolve().parents[2])
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.engine.rpc", "--port", "0",
           "--n-out", str(n_out), "--delay-ms", str(int(delay_s * 1000)),
           "--loss-prob", str(loss_prob),
           "--jitter-ms", str(int(jitter_s * 1000))]
    if secret is not None:
        cmd += ["--secret", secret]
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, env=env, text=True)
    try:
        line = proc.stdout.readline()
        if not line.startswith("PORT "):
            raise RuntimeError(f"label server failed to start: {line!r}")
        yield "127.0.0.1", int(line.split()[1])
    finally:
        proc.terminate()
        proc.wait(timeout=10)


def _selftest() -> int:
    """Round trips over a subprocess loopback server (CI smoke): v2 and v1
    per-tenant clients, the batched shared-connection client with two
    tenants, then HMAC auth and an unauthenticated client against a
    secured server (must get nothing)."""
    s, n_out = 4, 6
    feats = np.zeros((s, 3), np.float32)
    mask = np.ones((s,), bool)

    def drain(teacher, timeout=10.0):
        deadline = time.monotonic() + timeout
        replies = []
        while not replies and time.monotonic() < deadline:
            replies = teacher.poll(0)
            if not replies and teacher.in_flight() == 0:
                replies = teacher.poll(0)
                break
            time.sleep(0.01)
        return replies

    def roundtrip(host, port, secret=None, timeout_s=10.0, wire="v2"):
        with RpcTeacher(host, port, timeout_s=timeout_s, secret=secret,
                        wire=wire) as teacher:
            ticket = teacher.ask(feats, mask, tick=3)
            replies = drain(teacher, timeout=min(timeout_s, 10.0))
            return ticket, replies

    want = [expected_label(3, i, n_out) for i in range(s)]
    with loopback_server(n_out=n_out) as (host, port):
        for wire in WIRE_FORMATS:
            ticket, replies = roundtrip(host, port, wire=wire)
            assert replies and replies[0].ticket == ticket, f"no {wire} reply"
            assert replies[0].labels.tolist() == want, (wire, replies[0].labels)
        # Batched shared connection: two tenants, one socket, one frame
        # carrying both asks (window generous enough to coalesce them).
        with BatchedRpcClient(host, port, timeout_s=10.0,
                              batch_window_s=0.2) as client:
            a, b = client.tenant("a"), client.tenant("b")
            a.ask(feats, mask, tick=3)
            b.ask(feats, mask, tick=3)
            ra, rb = drain(a), drain(b)
            assert ra and ra[0].labels.tolist() == want, "batched tenant a"
            assert rb and rb[0].labels.tolist() == want, "batched tenant b"
            assert client.wire_messages == 1 and client.asks_sent == 2, (
                client.wire_messages, client.asks_sent)
    # Compressed envelopes against an in-process server (for counter
    # access): answered in kind, metered, and byte-identical labels.
    # A wide tick so the win is unambiguous (real feature payloads
    # dominate the frame, exactly the bytes zlib earns its keep on).
    s_z = 64
    feats_z = np.zeros((s_z, 8), np.float32)
    want_z = [expected_label(3, i, n_out) for i in range(s_z)]
    server = LabelServer(port=0, n_out=n_out).start()
    try:
        with RpcTeacher("127.0.0.1", server.port, timeout_s=10.0,
                        compress=True) as teacher:
            ticket = teacher.ask(feats_z, np.ones((s_z,), bool), tick=3)
            replies = drain(teacher)
            assert replies and replies[0].ticket == ticket, "no zlib reply"
            assert replies[0].labels.tolist() == want_z, replies[0].labels
        assert server.frames_compressed == 1, server.frames_compressed
        assert server.raw_bytes_in > server.compressed_bytes_in > 0, (
            server.raw_bytes_in, server.compressed_bytes_in)
        assert server.raw_bytes_out >= server.compressed_bytes_out > 0, (
            server.raw_bytes_out, server.compressed_bytes_out)
    finally:
        server.close()
    with loopback_server(n_out=n_out, secret="s3cr3t") as (host, port):
        ticket, replies = roundtrip(host, port, secret="s3cr3t")
        assert replies and replies[0].labels.tolist() == want, "auth roundtrip"
        # Unauthenticated client: the server closes the connection; the ask
        # times out into loss and no label ever arrives.
        _, replies = roundtrip(host, port, secret=None, timeout_s=0.5)
        assert not replies, "unauthenticated client must receive nothing"
    # odlint: disable=ODL005 -- CLI selftest result line, not library code
    print("rpc selftest OK (v1 + v2 + zlib + batched + hmac + reject):", want)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--n-out", type=int, default=6)
    ap.add_argument("--delay-ms", type=int, default=0,
                    help="server-side per-request delay (timeout testing)")
    ap.add_argument("--jitter-ms", type=int, default=0,
                    help="extra uniform per-reply delay in [0, J] ms")
    ap.add_argument("--loss-prob", type=float, default=0.0,
                    help="fraction of asks never answered (client deadline "
                    "maps them to loss)")
    ap.add_argument("--secret", default=None,
                    help="shared secret: require the HMAC challenge-response "
                    "handshake on every connection")
    ap.add_argument("--selftest", action="store_true",
                    help="spawn a loopback server and round-trip one ask")
    args = ap.parse_args(argv)
    if args.selftest:
        return _selftest()
    server = LabelServer(port=args.port, n_out=args.n_out,
                         delay_s=args.delay_ms / 1000.0, secret=args.secret,
                         loss_prob=args.loss_prob,
                         jitter_s=args.jitter_ms / 1000.0)
    # odlint: disable=ODL005 -- CLI contract: launchers parse this PORT line
    print(f"PORT {server.port}", flush=True)
    server.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
