"""odlint CLI: run the repo-native rule set over source trees.

Usage:
  odlint [paths...] [--format text|json] [--output FILE]
         [--baseline FILE] [--write-baseline] [--rules ODL001,ODL004]
         [--list-rules]

Exit status: 0 when no (unbaselined) findings, 1 otherwise, 2 on usage
errors.  Stdlib-only — safe to run in CI before jax is installed.
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from . import core
from .rules import ALL_RULES


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="odlint", description="repo-native static analysis for the ODL runtime"
    )
    p.add_argument(
        "paths",
        nargs="*",
        default=["src/repro"],
        help="files or directories to lint (default: src/repro)",
    )
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--output", help="write the report here instead of stdout")
    p.add_argument(
        "--baseline",
        help="JSON baseline of accepted fingerprints; matching findings "
        "are reported but do not fail the run",
    )
    p.add_argument(
        "--write-baseline",
        action="store_true",
        help="write the current findings to --baseline and exit 0",
    )
    p.add_argument(
        "--rules",
        help="comma-separated rule IDs to run (default: all)",
    )
    p.add_argument("--list-rules", action="store_true")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.rule_id}  {rule.title}")
            print(f"        rationale: {rule.rationale}")
        return 0

    rules = list(ALL_RULES)
    if args.rules:
        want = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = want - {r.rule_id for r in rules}
        if unknown:
            print(f"odlint: unknown rule id(s): {sorted(unknown)}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.rule_id in want]

    files = core.collect_files(args.paths)
    if not files:
        print(f"odlint: no .py files under {args.paths}", file=sys.stderr)
        return 2

    t0 = time.perf_counter()
    project = core.Project.load(files, root=Path.cwd())
    findings = core.run_rules(project, rules)
    elapsed = time.perf_counter() - t0

    if args.write_baseline:
        if not args.baseline:
            print("odlint: --write-baseline requires --baseline", file=sys.stderr)
            return 2
        core.write_baseline(Path(args.baseline), findings)
        print(
            f"odlint: wrote {len(findings)} fingerprint(s) to {args.baseline}"
        )
        return 0

    baseline = core.load_baseline(Path(args.baseline)) if args.baseline else set()
    blocking = core.apply_baseline(findings, baseline)

    if args.format == "json":
        report = core.report_json(findings, rules)
    else:
        report = core.report_text(findings)
        report += (
            f"\nodlint: scanned {len(project.modules)} file(s) in "
            f"{elapsed:.2f}s, {len(blocking)} blocking"
        )
    if args.output:
        Path(args.output).write_text(report + "\n")
    else:
        print(report)
    return 1 if blocking else 0


if __name__ == "__main__":
    sys.exit(main())
