"""odlint — repo-native static analysis for the ODL runtime.

AST-based rules that turn the repo's cross-file invariants (lock
discipline, donation safety, counter mirroring, wire-protocol
exhaustiveness, sharding scope) into parse-time checks.  See
``src/repro/analysis/README.md`` for the rule catalog and
``tools/odlint`` / ``python -m repro.analysis.cli`` for the CLI.
"""

from .core import Finding, Module, Project, Rule, run_rules  # noqa: F401
from .rules import ALL_RULES  # noqa: F401
