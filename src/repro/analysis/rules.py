"""odlint rules: the repo's cross-file invariants as parse-time checks.

Each rule is a ``core.Rule`` subclass with a stable ID, a one-line
rationale naming the bug/PR that motivated it, and fixture-backed
golden tests in ``tests/test_odlint.py``.  Rule catalog with full
rationale: ``src/repro/analysis/README.md``.
"""

from __future__ import annotations

import ast
from typing import Iterable, Optional

from .core import Finding, Module, Project, Rule, call_name, dotted, str_const

# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

_LOCK_CTORS = {
    "threading.Lock",
    "threading.RLock",
    "threading.Condition",
    "lockdebug.make_lock",
    "lockdebug.make_rlock",
    "lockdebug.make_condition",
    "make_lock",
    "make_rlock",
    "make_condition",
}

def _self_attr(node: ast.AST) -> Optional[str]:
    """'attr' when node is ``self.attr``, else None."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _iter_classes(tree: ast.Module) -> Iterable[ast.ClassDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            yield node


def _iter_funcs(node: ast.AST) -> Iterable[ast.FunctionDef]:
    for child in ast.walk(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield child


def _assigned_self_attrs(stmt: ast.stmt) -> list:
    """(attr, node) pairs for every self.<attr> write in one statement.

    Covers ``self.a = ...``, ``self.a += ...``, ``self.a[k] = ...``,
    ``del self.a[k]``, and tuple targets.  Method-call mutators
    (``self.a.append(...)``) are deliberately untracked: too many false
    positives on single-threaded helper containers.
    """
    out = []

    def visit_target(t: ast.AST) -> None:
        attr = _self_attr(t)
        if attr is not None:
            out.append((attr, t))
            return
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                visit_target(el)
        elif isinstance(t, ast.Subscript):
            attr = _self_attr(t.value)
            if attr is not None:
                out.append((attr, t))
        elif isinstance(t, ast.Starred):
            visit_target(t.value)

    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            visit_target(t)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        if stmt.target is not None:
            visit_target(stmt.target)
    elif isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            visit_target(t)
    return out


def _with_held_locks(with_node: ast.With) -> list:
    """Lock attrs acquired by ``with self.<lock>:`` items."""
    held = []
    for item in with_node.items:
        ctx = item.context_expr
        attr = _self_attr(ctx)
        if attr is not None:
            held.append(attr)
            continue
        # with self._cond: via a Condition is the same acquire; also
        # accept self._lock.acquire-style helpers spelled as calls
        if isinstance(ctx, ast.Call):
            attr = _self_attr(ctx.func)
            if attr is not None:
                held.append(attr)
    return held


# ---------------------------------------------------------------------------
# ODL001 — lock discipline
# ---------------------------------------------------------------------------


class LockDisciplineRule(Rule):
    """Writes to guarded attributes of threaded classes must hold the lock.

    A class is *threaded* when it owns a lock attribute (assigned from
    ``threading.Lock/RLock/Condition()`` or ``lockdebug.make_*``) and
    either spawns a ``threading.Thread`` or carries an explicit
    ``guarded-by`` annotation.  An attribute is *guarded* when at least
    one write outside ``__init__`` happens under ``with self.<lock>:``
    (inference), or when any of its writes carries
    ``# odlint: guarded-by(<lock>)``.  Every other write to that
    attribute outside ``__init__`` must then hold the same lock, be
    inside a method annotated ``# odlint: holds-lock(<lock>)``, or be
    suppressed with a reason.
    """

    rule_id = "ODL001"
    title = "unguarded write to a lock-protected attribute"
    rationale = (
        "PR 5 shipped unsynchronized socket writes that interleaved "
        "partial frames; PR 10 found SpanTracer.dropped mutated outside "
        "its lock"
    )

    def check_module(self, module: Module, project: Project):
        for cls in _iter_classes(module.tree):
            yield from self._check_class(module, cls)

    def _check_class(self, module: Module, cls: ast.ClassDef):
        lock_attrs = self._lock_attrs(cls)
        if not lock_attrs:
            return

        # Gather every self-attr write with its context:
        # (attr, node, held_locks, func)
        writes = []
        for func in self._methods(cls):
            # the annotation may sit on the def line, anywhere in a
            # multi-line signature, or standalone directly above the def
            holds = {
                a.lock
                for a in module.annotation_in_range(
                    func.lineno - 1,
                    func.body[0].lineno if func.body else func.lineno,
                    "holds-lock",
                )
            }
            self._collect_writes(module, func, func.body, set(holds), writes)

        # Explicit guarded-by annotations on write lines
        guarded: dict[str, set] = {}
        for attr, node, held, func in writes:
            for a in module.annotations_on(node.lineno, "guarded-by"):
                guarded.setdefault(attr, set()).add(a.lock)

        # Inference: owning a lock marks the class threaded (the lock
        # exists *because* of cross-thread access — SpanTracer never
        # spawns a Thread itself yet is mutated from every session
        # thread).  An attr is guarded by the intersection of held-lock
        # sets over its non-__init__ locked writes, unless an explicit
        # annotation already names a lock.
        locked_by_attr: dict[str, list] = {}
        for attr, node, held, func in writes:
            if func.name == "__init__" or attr in lock_attrs:
                continue
            locked_by_attr.setdefault(attr, []).append(held & lock_attrs)
        for attr, heldsets in locked_by_attr.items():
            if attr in guarded:
                continue
            nonempty = [h for h in heldsets if h]
            if not nonempty:
                continue
            common = set.intersection(*nonempty)
            if common:
                guarded[attr] = common

        for attr, node, held, func in writes:
            if func.name == "__init__" or attr not in guarded:
                continue
            want = guarded[attr]
            if held & want:
                continue
            lock_name = sorted(want)[0]
            yield Finding(
                rule=self.rule_id,
                path=module.path,
                line=node.lineno,
                message=(
                    f"{cls.name}.{attr} is guarded by self.{lock_name} but "
                    f"written here without holding it"
                ),
                hint=(
                    f"wrap in 'with self.{lock_name}:' or annotate the "
                    f"enclosing def with '# odlint: holds-lock({lock_name})'"
                ),
            )

    def _methods(self, cls: ast.ClassDef) -> list:
        return [
            n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]

    def _lock_attrs(self, cls: ast.ClassDef) -> set:
        attrs = set()
        for func in self._methods(cls):
            for stmt in ast.walk(func):
                if not isinstance(stmt, ast.Assign):
                    continue
                if not isinstance(stmt.value, ast.Call):
                    continue
                if call_name(stmt.value) not in _LOCK_CTORS:
                    continue
                for t in stmt.targets:
                    attr = _self_attr(t)
                    if attr is not None:
                        attrs.add(attr)
        return attrs

    def _collect_writes(self, module, func, body, held, out) -> None:
        """Walk statements tracking the set of held self-locks."""
        for stmt in body:
            if isinstance(stmt, ast.With):
                inner = set(held) | set(_with_held_locks(stmt))
                self._collect_writes(module, func, stmt.body, inner, out)
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested defs (thread targets): fresh lock context
                self._collect_writes(module, stmt, stmt.body, set(), out)
                continue
            for attr, node in _assigned_self_attrs(stmt):
                out.append((attr, node, set(held), func))
            # recurse into compound statements
            for field_body in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field_body, None)
                if isinstance(sub, list) and sub:
                    self._collect_writes(module, func, sub, held, out)
            for handler in getattr(stmt, "handlers", []) or []:
                self._collect_writes(module, func, handler.body, held, out)


# ---------------------------------------------------------------------------
# ODL002 — donation safety
# ---------------------------------------------------------------------------


class DonationSafetyRule(Rule):
    """No read of a value after it was passed at a donated position.

    Module scan finds runner factories — functions whose return value is
    ``jax.jit(f, donate_argnums=...)`` — and maps factory name → donated
    positions.  Inside every function, calls through a variable or
    ``self.<attr>`` bound to such a factory mark the Name / self-attr
    arguments at donated positions dead; a later load of a dead name is
    a finding.  Reassignment (including in the same statement, the
    repo's idiom) revives it.  ``If`` branches merge dead sets by
    union (a read that is dead on any path is flagged); loops are
    checked one pass, conservatively.
    """

    rule_id = "ODL002"
    title = "use after donation to a jitted runner"
    rationale = (
        "donated buffers are invalidated by XLA; reading one returns "
        "garbage or raises only on some backends (engine/stream.py "
        "double-buffer idiom makes this easy to get wrong)"
    )

    def check_module(self, module: Module, project: Project):
        factories = self._donating_factories(module.tree)
        if not factories:
            return
        bindings = self._bindings(module.tree, factories)
        for func in _iter_funcs(module.tree):
            yield from self._check_func(module, func, factories, bindings)

    # -- factory discovery --------------------------------------------------

    def _donating_factories(self, tree: ast.Module) -> dict:
        """name -> set of donated positional indices."""
        out = {}
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for ret in ast.walk(node):
                if not isinstance(ret, ast.Return) or ret.value is None:
                    continue
                nums = self._jit_donate_argnums(ret.value)
                if nums:
                    out[node.name] = nums
        return out

    def _jit_donate_argnums(self, node: ast.AST) -> set:
        if not isinstance(node, ast.Call):
            return set()
        if call_name(node) not in ("jax.jit", "jit"):
            return set()
        for kw in node.keywords:
            if kw.arg != "donate_argnums":
                continue
            return self._argnum_values(kw.value)
        return set()

    def _argnum_values(self, node: ast.AST) -> set:
        """Constant tuple → indices; IfExp → union of both arms."""
        if isinstance(node, ast.IfExp):
            return self._argnum_values(node.body) | self._argnum_values(node.orelse)
        if isinstance(node, ast.Tuple):
            out = set()
            for el in node.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, int):
                    out.add(el.value)
            return out
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return {node.value}
        return set()

    def _bindings(self, tree: ast.Module, factories: dict) -> dict:
        """'name' or 'self.attr' -> donated positions, from assignments."""
        out = {}
        for node in ast.walk(tree):
            if not isinstance(node, ast.Assign):
                continue
            if not isinstance(node.value, ast.Call):
                continue
            fname = call_name(node.value)
            if fname not in factories:
                continue
            for t in node.targets:
                key = self._value_key(t)
                if key:
                    out[key] = factories[fname]
        return out

    # -- per-function dataflow ----------------------------------------------

    def _value_key(self, node: ast.AST) -> str:
        if isinstance(node, ast.Name):
            return node.id
        attr = _self_attr(node)
        if attr is not None:
            return f"self.{attr}"
        return ""

    def _check_func(self, module, func, factories, bindings):
        findings: list[Finding] = []
        self._walk(module, func.body, factories, bindings, set(), findings)
        return findings

    def _walk(self, module, body, factories, bindings, dead, findings) -> None:
        """dead: set of value-keys whose buffer was donated."""
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._walk(module, stmt.body, factories, bindings, set(), findings)
                continue
            if isinstance(stmt, ast.If):
                d1 = set(dead)
                d2 = set(dead)
                self._stmt_reads(module, stmt.test, dead, findings)
                self._walk(module, stmt.body, factories, bindings, d1, findings)
                self._walk(module, stmt.orelse, factories, bindings, d2, findings)
                dead.clear()
                dead.update(d1 | d2)
                continue
            if isinstance(stmt, (ast.For, ast.While)):
                if isinstance(stmt, ast.For):
                    self._stmt_reads(module, stmt.iter, dead, findings)
                    dead.discard(self._value_key(stmt.target))
                else:
                    self._stmt_reads(module, stmt.test, dead, findings)
                self._walk(module, stmt.body, factories, bindings, dead, findings)
                self._walk(module, stmt.orelse, factories, bindings, dead, findings)
                continue
            if isinstance(stmt, (ast.With, ast.Try)):
                for field_body in ("body", "orelse", "finalbody"):
                    sub = getattr(stmt, field_body, None)
                    if isinstance(sub, list):
                        self._walk(module, sub, factories, bindings, dead, findings)
                for handler in getattr(stmt, "handlers", []) or []:
                    self._walk(module, handler.body, factories, bindings, dead,
                               findings)
                continue

            # simple statement: reads first (RHS), then donation marks,
            # then assignment targets revive.
            value = getattr(stmt, "value", None)
            donated_now = []
            if value is not None:
                for call in [n for n in ast.walk(value) if isinstance(n, ast.Call)]:
                    nums = self._call_donations(call, factories, bindings)
                    if not nums:
                        continue
                    # a *args splat makes positional indices unknowable —
                    # skip rather than mis-attribute donation
                    if any(isinstance(a, ast.Starred) for a in call.args):
                        continue
                    for i in nums:
                        if i < len(call.args):
                            key = self._value_key(call.args[i])
                            if key:
                                donated_now.append((key, call))
                self._stmt_reads(module, value, dead, findings)
            for key, call in donated_now:
                dead.add(key)
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    self._revive_target(t, dead)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                if isinstance(stmt, ast.AugAssign):
                    self._stmt_reads(module, stmt.target, dead, findings)
                if stmt.target is not None:
                    self._revive_target(stmt.target, dead)

    def _call_donations(self, call, factories, bindings) -> set:
        key = ""
        if isinstance(call.func, ast.Name):
            key = call.func.id
        else:
            attr = _self_attr(call.func)
            if attr is not None:
                key = f"self.{attr}"
        if key in bindings:
            return bindings[key]
        if key in factories:
            return set()  # calling the factory itself donates nothing
        return set()

    def _revive_target(self, t: ast.AST, dead: set) -> None:
        key = self._value_key(t)
        if key:
            dead.discard(key)
            return
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                self._revive_target(el, dead)
        elif isinstance(t, ast.Starred):
            self._revive_target(t.value, dead)

    def _stmt_reads(self, module, expr, dead, findings) -> None:
        if expr is None or not dead:
            return
        for node in ast.walk(expr):
            key = ""
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                key = node.id
            else:
                attr = _self_attr(node)
                if attr is not None and isinstance(
                    getattr(node, "ctx", ast.Load()), ast.Load
                ):
                    key = f"self.{attr}"
            if key and key in dead:
                findings.append(
                    Finding(
                        rule=self.rule_id,
                        path=module.path,
                        line=node.lineno,
                        message=(
                            f"'{key}' is read after being passed at a donated "
                            f"position of a jitted runner"
                        ),
                        hint=(
                            "rebind the result over the donated name in the "
                            "same statement, or drop donation for this arg"
                        ),
                    )
                )
                dead.discard(key)  # report each donation once


# ---------------------------------------------------------------------------
# ODL003 — counter-mirror completeness
# ---------------------------------------------------------------------------


class CounterMirrorRule(Rule):
    """StreamStats fields ⊆ telemetry mirror ∪ exclusions; identity keys exist.

    Statically re-derives PR 9's runtime growth guard: every field of
    ``StreamStats`` must appear in ``telemetry.STREAM_COUNTER_FIELDS``,
    ``STREAM_GAUGE_FIELDS``, or ``STREAM_MIRROR_EXCLUDED``; every name
    in those telemetry tuples must exist on ``StreamStats``; and every
    counter named in ``elastic.reconcile``'s identity key tuple must be
    a mirrored counter.
    """

    rule_id = "ODL003"
    title = "StreamStats / telemetry mirror drift"
    rationale = (
        "PR 9 locked the registry view identical to StreamStats with a "
        "runtime growth guard; this catches the drift at parse time"
    )

    def check_project(self, project: Project):
        stream = project.find("engine.stream")
        telem = project.find("runtime.telemetry")
        if stream is None or telem is None:
            return

        fields = self._dataclass_fields(stream, "StreamStats")
        if fields is None:
            return
        mirrors = {}
        for name in ("STREAM_COUNTER_FIELDS", "STREAM_GAUGE_FIELDS",
                     "STREAM_MIRROR_EXCLUDED"):
            val = self._str_tuple(telem, name)
            if val is None:
                yield Finding(
                    rule=self.rule_id,
                    path=telem.path,
                    line=1,
                    message=f"telemetry is missing the {name} tuple",
                    hint="define it next to sync_stream_stats",
                )
                val = ((), 1)
            mirrors[name] = val
        mirrored = set()
        for name, (vals, line) in mirrors.items():
            for v in vals:
                if v not in fields:
                    yield Finding(
                        rule=self.rule_id,
                        path=telem.path,
                        line=line,
                        message=(
                            f"{name} names '{v}' which is not a StreamStats "
                            f"field"
                        ),
                        hint="remove it or add the field to StreamStats",
                    )
            mirrored |= set(vals)
        for fname, fline in fields.items():
            if fname not in mirrored:
                yield Finding(
                    rule=self.rule_id,
                    path=stream.path,
                    line=fline,
                    message=(
                        f"StreamStats.{fname} is neither mirrored "
                        f"(STREAM_COUNTER_FIELDS/STREAM_GAUGE_FIELDS) nor "
                        f"excluded (STREAM_MIRROR_EXCLUDED) in telemetry"
                    ),
                    hint="add it to the mirror or the explicit exclusion set",
                )

        # identity keys in elastic.reconcile must be mirrored counters
        elastic = project.find("runtime.elastic")
        counters = set(mirrors["STREAM_COUNTER_FIELDS"][0])
        if elastic is not None and counters:
            for node in ast.walk(elastic.tree):
                if not (isinstance(node, ast.FunctionDef)
                        and node.name == "reconcile"):
                    continue
                for sub in ast.walk(node):
                    if not isinstance(sub, ast.Assign):
                        continue
                    if not any(
                        isinstance(t, ast.Name) and t.id == "keys"
                        for t in sub.targets
                    ):
                        continue
                    if not isinstance(sub.value, ast.Tuple):
                        continue
                    for el in sub.value.elts:
                        s = str_const(el)
                        if s is not None and s not in counters:
                            yield Finding(
                                rule=self.rule_id,
                                path=elastic.path,
                                line=el.lineno,
                                message=(
                                    f"reconcile() keys names '{s}' which is "
                                    f"not a mirrored StreamStats counter"
                                ),
                                hint=(
                                    "fix the key or add the counter to "
                                    "STREAM_COUNTER_FIELDS"
                                ),
                            )

    def _dataclass_fields(self, module: Module, cls_name: str) -> Optional[dict]:
        for cls in _iter_classes(module.tree):
            if cls.name != cls_name:
                continue
            fields = {}
            for stmt in cls.body:
                if isinstance(stmt, ast.AnnAssign) and isinstance(
                    stmt.target, ast.Name
                ):
                    fields[stmt.target.id] = stmt.lineno
            return fields
        return None

    def _str_tuple(self, module: Module, name: str):
        """((values...), lineno) for a module-level tuple of str consts."""
        for stmt in module.tree.body:
            if not isinstance(stmt, ast.Assign):
                continue
            if not any(
                isinstance(t, ast.Name) and t.id == name for t in stmt.targets
            ):
                continue
            vals = []
            value = stmt.value
            if isinstance(value, ast.Call) and call_name(value) in (
                "frozenset", "set", "tuple"
            ):
                value = value.args[0] if value.args else ast.Tuple(elts=[])
            if isinstance(value, (ast.Tuple, ast.List, ast.Set)):
                for el in value.elts:
                    s = str_const(el)
                    if s is not None:
                        vals.append(s)
            return tuple(vals), stmt.lineno
        return None


# ---------------------------------------------------------------------------
# ODL004 — wire-protocol exhaustiveness
# ---------------------------------------------------------------------------


class WireExhaustivenessRule(Rule):
    """Every sent control 'kind' has a worker handler branch, and back.

    Sent kinds: string literals under a ``"kind"`` key in dict literals
    passed to ``self._request(...)`` / ``_encode_frame(...)`` in
    ``runtime/elastic.py``.  Handled kinds: string literals compared
    against (a variable assigned from) ``header.get("kind")`` in
    ``runtime/worker.py``.  Also: ``snapshot.py`` must reference the
    frame version symbolically (``rpc_mod.WIRE_V2`` / ``WIRE_V2``), not
    re-declare a literal version byte that can drift from ``rpc.py``.
    """

    rule_id = "ODL004"
    title = "wire 'kind' without a matching handler (or version drift)"
    rationale = (
        "PR 8's control protocol grows a kind per feature (metrics came "
        "in PR 9); a sent-but-unhandled kind fails at runtime on the "
        "first scrape"
    )

    def check_project(self, project: Project):
        elastic = project.find("runtime.elastic")
        worker = project.find("runtime.worker")
        if elastic is not None and worker is not None:
            sent = self._sent_kinds(elastic)
            handled = self._handled_kinds(worker)
            for kind, line in sorted(sent.items()):
                if kind not in handled:
                    yield Finding(
                        rule=self.rule_id,
                        path=elastic.path,
                        line=line,
                        message=(
                            f"control kind '{kind}' is sent by WorkerClient "
                            f"but has no handler branch in runtime/worker.py"
                        ),
                        hint="add a branch on header.get('kind') in Worker._handle",
                    )
            for kind, line in sorted(handled.items()):
                if kind not in sent:
                    yield Finding(
                        rule=self.rule_id,
                        path=worker.path,
                        line=line,
                        message=(
                            f"worker handles control kind '{kind}' that no "
                            f"WorkerClient call site sends (dead protocol arm)"
                        ),
                        hint="remove the branch or add the client sender",
                    )

        snapshot = project.find("engine.snapshot")
        rpc = project.find("engine.rpc")
        if snapshot is not None and rpc is not None:
            yield from self._check_version_bytes(snapshot, rpc)

    def _sent_kinds(self, module: Module) -> dict:
        out = {}
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = dotted(node.func)
            if not (fname.endswith("._request") or fname.endswith("_encode_frame")
                    or fname == "_encode_frame"):
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if not isinstance(arg, ast.Dict):
                    continue
                for k, v in zip(arg.keys, arg.values):
                    if k is not None and str_const(k) == "kind":
                        s = str_const(v)
                        if s is not None:
                            out.setdefault(s, v.lineno)
        return out

    def _handled_kinds(self, module: Module) -> dict:
        # variables assigned from <x>.get("kind") — only those; a loop
        # variable merely *named* "kind" (e.g. the frame-format tag from
        # rpc._iter_wire) is not a control kind
        kind_vars = set()
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                call = node.value
                if (
                    dotted(call.func).endswith(".get")
                    and call.args
                    and str_const(call.args[0]) == "kind"
                ):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            kind_vars.add(t.id)
        out = {}
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Compare):
                operands = [node.left] + list(node.comparators)
                refs_kind = any(
                    (isinstance(o, ast.Name) and o.id in kind_vars)
                    or (
                        isinstance(o, ast.Call)
                        and dotted(o.func).endswith(".get")
                        and o.args
                        and str_const(o.args[0]) == "kind"
                    )
                    for o in operands
                )
                if not refs_kind:
                    continue
                for o in operands:
                    s = str_const(o)
                    if s is not None:
                        out.setdefault(s, o.lineno)
                    elif isinstance(o, (ast.Tuple, ast.List, ast.Set)):
                        for el in o.elts:
                            s = str_const(el)
                            if s is not None:
                                out.setdefault(s, el.lineno)
        return out

    def _check_version_bytes(self, snapshot: Module, rpc: Module):
        rpc_versions = {}
        for stmt in rpc.tree.body:
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Name) and t.id.startswith("WIRE_V"):
                        if isinstance(stmt.value, ast.Constant):
                            rpc_versions[t.id] = stmt.value.value
        if not rpc_versions:
            return
        # snapshot.py must not re-declare a WIRE_V* literal of its own
        for stmt in snapshot.tree.body:
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Name) and t.id.startswith("WIRE_V"):
                        if (
                            isinstance(stmt.value, ast.Constant)
                            and stmt.value.value != rpc_versions.get(t.id)
                        ):
                            yield Finding(
                                rule=self.rule_id,
                                path=snapshot.path,
                                line=stmt.lineno,
                                message=(
                                    f"snapshot re-declares {t.id} with a "
                                    f"value that drifts from rpc.py"
                                ),
                                hint="import the constant from engine.rpc instead",
                            )
        # snapshot's frame-magic check must reference rpc's symbol
        uses_symbol = any(
            isinstance(n, (ast.Attribute, ast.Name))
            and dotted(n).split(".")[-1] in rpc_versions
            for n in ast.walk(snapshot.tree)
        )
        if not uses_symbol:
            yield Finding(
                rule=self.rule_id,
                path=snapshot.path,
                line=1,
                message=(
                    "snapshot never references rpc's WIRE_V* symbols — its "
                    "frame magic check can silently drift from the wire format"
                ),
                hint="compare against rpc_mod.WIRE_V2 (symbol, not literal)",
            )


# ---------------------------------------------------------------------------
# ODL005 — forbidden APIs
# ---------------------------------------------------------------------------


class ForbiddenApiRule(Rule):
    """Wall-clock/global RNG in jitted plan paths, bare except on socket
    paths, print() in the engine.

    * ``time.time``/``time.perf_counter``/``np.random.*``/
      ``numpy.random.*`` calls inside any function that is jitted
      (decorated with jax.jit/partial(jax.jit,...) or returned through
      ``jax.jit(...)``) — traced once, frozen forever.
    * ``except:`` (bare) in modules that import ``socket`` — swallows
      KeyboardInterrupt/SystemExit on serving threads.
    * ``print(...)`` anywhere under ``src/repro/engine/`` — the engine
      is a library; humans read telemetry, not stdout.
    """

    rule_id = "ODL005"
    title = "forbidden API on a hot/serving path"
    rationale = (
        "time.time inside a jitted fn is trace-time constant folding in "
        "disguise; bare except on the PR 5 socket threads ate shutdown "
        "signals during debugging"
    )

    _CLOCKS = {"time.time", "time.perf_counter", "time.monotonic"}

    def check_module(self, module: Module, project: Project):
        jitted = self._jitted_funcs(module.tree)
        for func in jitted:
            for node in ast.walk(func):
                if not isinstance(node, ast.Call):
                    continue
                fname = call_name(node)
                if fname in self._CLOCKS or fname.startswith(
                    ("np.random.", "numpy.random.")
                ):
                    yield Finding(
                        rule=self.rule_id,
                        path=module.path,
                        line=node.lineno,
                        message=(
                            f"'{fname}' inside jitted '{func.name}' is frozen "
                            f"at trace time"
                        ),
                        hint="pass the value in as an argument / use jax PRNG keys",
                    )
        if self._imports(module.tree, "socket"):
            for node in ast.walk(module.tree):
                if isinstance(node, ast.ExceptHandler) and node.type is None:
                    yield Finding(
                        rule=self.rule_id,
                        path=module.path,
                        line=node.lineno,
                        message=(
                            "bare 'except:' in a socket-handling module "
                            "swallows KeyboardInterrupt/SystemExit"
                        ),
                        hint="catch Exception (or OSError) instead",
                    )
        if "/engine/" in module.path.replace("\\", "/") or (
            ".engine." in f".{module.name}."
        ):
            for node in ast.walk(module.tree):
                if (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "print"
                ):
                    yield Finding(
                        rule=self.rule_id,
                        path=module.path,
                        line=node.lineno,
                        message="print() in src/repro/engine/ (library code)",
                        hint="use telemetry spans/counters or return the value",
                    )

    def _jitted_funcs(self, tree: ast.Module) -> list:
        out = []
        jitted_names = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and call_name(node) in ("jax.jit", "jit"):
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Name):
                        jitted_names.add(arg.id)
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if node.name in jitted_names:
                out.append(node)
                continue
            for dec in node.decorator_list:
                d = dotted(dec) or (
                    call_name(dec) if isinstance(dec, ast.Call) else ""
                )
                if "jit" in d.split("."):
                    out.append(node)
                    break
                if isinstance(dec, ast.Call) and any(
                    "jit" in dotted(a).split(".") for a in dec.args
                ):
                    out.append(node)
                    break
        return out

    def _imports(self, tree: ast.Module, name: str) -> bool:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                if any(alias.name == name for alias in node.names):
                    return True
            elif isinstance(node, ast.ImportFrom):
                if node.module == name:
                    return True
        return False


# ---------------------------------------------------------------------------
# ODL006 — sharding scope
# ---------------------------------------------------------------------------


class ShardingScopeRule(Rule):
    """Shard-local calls inside ``activate(mesh)`` need ``deactivate()``.

    Functions annotated ``# odlint: shard-local`` on their ``def`` line
    issue single-device dispatches.  Any call to one of them that sits
    lexically inside a ``with sharding.activate(...)`` / ``with
    activate(...)`` block must be nested under a ``with
    sharding.deactivate():`` — otherwise GSPMD constraints from the
    active mesh leak into the shard-local trace (the exact bug PR 7 hit
    twice).
    """

    rule_id = "ODL006"
    title = "shard-local dispatch under an active mesh without deactivate()"
    rationale = (
        "PR 7 hit this twice: per-shard sessions traced under the fleet "
        "mesh pick up full-width GSPMD constraints and either OOM or "
        "silently gather"
    )

    def check_module(self, module: Module, project: Project):
        shard_local = self._shard_local_names(project)
        if not shard_local:
            return
        yield from self._scan(module, module.tree.body, shard_local,
                              in_activate=False, in_deactivate=False)

    def _shard_local_names(self, project: Project) -> set:
        # cached per project — this scans every function of every module
        cached = getattr(project, "_odl006_names", None)
        if cached is not None:
            return cached
        names = set()
        for mod in project.modules.values():
            for func in _iter_funcs(mod.tree):
                end = func.body[0].lineno if func.body else func.lineno
                if mod.annotation_in_range(func.lineno - 1, end, "shard-local"):
                    names.add(func.name)
        project._odl006_names = names
        return names

    def _with_kind(self, stmt: ast.With) -> str:
        for item in stmt.items:
            ctx = item.context_expr
            if isinstance(ctx, ast.Call):
                fname = dotted(ctx.func)
                last = fname.split(".")[-1]
                if last == "activate":
                    return "activate"
                if last == "deactivate":
                    return "deactivate"
        return ""

    def _scan(self, module, body, shard_local, in_activate, in_deactivate):
        for stmt in body:
            if isinstance(stmt, ast.With):
                kind = self._with_kind(stmt)
                if in_activate and not in_deactivate:
                    for item in stmt.items:
                        yield from self._check_expr(
                            module, item.context_expr, shard_local
                        )
                yield from self._scan(
                    module, stmt.body, shard_local,
                    in_activate or kind == "activate",
                    (in_deactivate or kind == "deactivate")
                    and kind != "activate",
                )
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # a nested def is not executed here; scan it with a
                # fresh scope (it may be called elsewhere)
                yield from self._scan(module, stmt.body, shard_local,
                                      False, False)
                continue
            if in_activate and not in_deactivate:
                # only this statement's own expressions — nested
                # statement bodies are handled by the recursion below
                # with their own (possibly deactivated) scope
                for expr in self._stmt_exprs(stmt):
                    yield from self._check_expr(module, expr, shard_local)
            # recurse into compound statements, preserving scope flags
            for field_body in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field_body, None)
                if isinstance(sub, list) and sub:
                    yield from self._scan(module, sub, shard_local,
                                          in_activate, in_deactivate)
            for handler in getattr(stmt, "handlers", []) or []:
                yield from self._scan(module, handler.body, shard_local,
                                      in_activate, in_deactivate)

    def _stmt_exprs(self, stmt: ast.stmt):
        """Direct expression children of a statement (no nested stmts)."""
        for _name, value in ast.iter_fields(stmt):
            if isinstance(value, ast.expr):
                yield value
            elif isinstance(value, list):
                for el in value:
                    if isinstance(el, ast.expr):
                        yield el

    def _check_expr(self, module, expr, shard_local):
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            fname = dotted(node.func).split(".")[-1]
            if fname in shard_local:
                yield Finding(
                    rule=self.rule_id,
                    path=module.path,
                    line=node.lineno,
                    message=(
                        f"shard-local '{fname}' called inside an "
                        f"activate(mesh) scope without sharding.deactivate()"
                    ),
                    hint="wrap the call in 'with sharding.deactivate():'",
                )


ALL_RULES = (
    LockDisciplineRule(),
    DonationSafetyRule(),
    CounterMirrorRule(),
    WireExhaustivenessRule(),
    ForbiddenApiRule(),
    ShardingScopeRule(),
)
