"""odlint core: findings, annotations, module/project model, rule runner.

Everything here is dependency-free (stdlib ``ast`` + ``tokenize`` only)
so the linter can run in CI environments without jax installed and
costs nothing to import.

Annotation grammar (all live in comments, parsed by tokenize so they
work on any line, including continuation lines):

  # odlint: disable=ODL001[,ODL005] -- <reason>
      Suppress findings of the listed rules on this line (or, when the
      comment is alone on a line, on the next code line).  The reason
      after ``--`` is REQUIRED: a suppression without one is itself a
      finding (ODL000) — zero bare suppressions, ever.

  # odlint: guarded-by(<lock>)
      Declares that the attribute assigned on this line is protected by
      ``self.<lock>`` — the lock-discipline rule then checks every
      write site of that attribute.

  # odlint: holds-lock(<lock>)
      On a ``def`` line: every caller of this method already holds
      ``self.<lock>``; writes inside it count as guarded.

  # odlint: shard-local
      On a ``def`` line: this function issues shard-local (single
      device) dispatches; when called inside an active ``activate(mesh)``
      scope it must sit under ``sharding.deactivate()``.
"""

from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Optional

# ---------------------------------------------------------------------------
# Findings
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Finding:
    """One lint finding, pointing at a source line.

    ``fingerprint`` deliberately omits the line number so baselines
    survive unrelated edits above the finding.
    """

    rule: str
    path: str
    line: int
    message: str
    hint: str = ""

    def format_text(self) -> str:
        s = f"{self.path}:{self.line} {self.rule} {self.message}"
        if self.hint:
            s += f"  [fix: {self.hint}]"
        return s

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.path}:{self.message}"

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "hint": self.hint,
            "fingerprint": self.fingerprint,
        }


# ---------------------------------------------------------------------------
# Comment annotations
# ---------------------------------------------------------------------------

_ODLINT_RE = re.compile(r"#\s*odlint:\s*(.+?)\s*$")
_DISABLE_RE = re.compile(r"disable=([A-Z0-9,\s]+?)(?:\s*--\s*(.*))?$")
_GUARDED_RE = re.compile(r"guarded-by\(\s*([A-Za-z_][A-Za-z0-9_]*)\s*\)")
_HOLDS_RE = re.compile(r"holds-lock\(\s*([A-Za-z_][A-Za-z0-9_]*)\s*\)")
_SHARD_LOCAL_RE = re.compile(r"shard-local\b")


@dataclass
class Annotation:
    """A parsed ``# odlint:`` comment at a specific source line."""

    line: int
    kind: str  # "disable" | "guarded-by" | "holds-lock" | "shard-local"
    rules: tuple = ()  # for disable
    reason: str = ""  # for disable
    lock: str = ""  # for guarded-by / holds-lock
    standalone: bool = False  # comment is alone on its line


def _parse_annotations(source: str, path: str) -> tuple:
    """Extract odlint annotations + raw comment map via tokenize.

    Returns (annotations, findings) — a malformed annotation is a
    finding (ODL000), never silently ignored.
    """
    annotations: list[Annotation] = []
    findings: list[Finding] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):  # pragma: no cover
        return [], []
    # A comment token is "standalone" when nothing but indentation
    # precedes it on its line.
    lines = source.splitlines()
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _ODLINT_RE.search(tok.string)
        if not m:
            continue
        body = m.group(1)
        lineno = tok.start[0]
        text_before = lines[lineno - 1][: tok.start[1]] if lineno <= len(lines) else ""
        standalone = not text_before.strip()
        dm = _DISABLE_RE.match(body)
        if dm:
            rules = tuple(r.strip() for r in dm.group(1).split(",") if r.strip())
            reason = (dm.group(2) or "").strip()
            annotations.append(
                Annotation(
                    line=lineno,
                    kind="disable",
                    rules=rules,
                    reason=reason,
                    standalone=standalone,
                )
            )
            if not reason:
                findings.append(
                    Finding(
                        rule="ODL000",
                        path=path,
                        line=lineno,
                        message=(
                            "bare suppression: 'odlint: disable' requires a "
                            "reason after ' -- '"
                        ),
                        hint="append ' -- <why this is safe>' to the comment",
                    )
                )
            continue
        gm = _GUARDED_RE.search(body)
        if gm:
            annotations.append(
                Annotation(line=lineno, kind="guarded-by", lock=gm.group(1),
                           standalone=standalone)
            )
            continue
        hm = _HOLDS_RE.search(body)
        if hm:
            annotations.append(
                Annotation(line=lineno, kind="holds-lock", lock=hm.group(1),
                           standalone=standalone)
            )
            continue
        if _SHARD_LOCAL_RE.search(body):
            annotations.append(
                Annotation(line=lineno, kind="shard-local", standalone=standalone)
            )
            continue
        findings.append(
            Finding(
                rule="ODL000",
                path=path,
                line=lineno,
                message=f"unrecognized odlint annotation: {body!r}",
                hint="see src/repro/analysis/README.md for the grammar",
            )
        )
    return annotations, findings


# ---------------------------------------------------------------------------
# Module / Project
# ---------------------------------------------------------------------------


@dataclass
class Module:
    """One parsed source file plus its odlint annotations."""

    path: str  # as given on the command line (relative ok)
    name: str  # dotted module name, e.g. "repro.engine.stream"
    source: str
    tree: ast.Module
    annotations: list = field(default_factory=list)
    parse_findings: list = field(default_factory=list)

    @classmethod
    def load(cls, path: Path, root: Optional[Path] = None) -> "Module":
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
        rel = path
        if root is not None:
            try:
                rel = path.relative_to(root)
            except ValueError:
                rel = path
        parts = list(rel.with_suffix("").parts)
        # Strip leading src/-style dirs so names read repro.engine.stream
        while parts and parts[0] in ("src", "."):
            parts.pop(0)
        name = ".".join(parts)
        annotations, findings = _parse_annotations(source, str(path))
        return cls(
            path=str(path),
            name=name,
            source=source,
            tree=tree,
            annotations=annotations,
            parse_findings=findings,
        )

    # -- annotation queries -------------------------------------------------

    def disables_for_line(self, line: int) -> list:
        """Disable annotations covering ``line``.

        A disable comment covers its own line, and — when it stands
        alone on a line — the next code line below it.
        """
        out = []
        for a in self.annotations:
            if a.kind != "disable":
                continue
            if a.line == line:
                out.append(a)
            elif a.standalone and line > a.line and self._next_code_line(a.line) == line:
                out.append(a)
        return out

    def _next_code_line(self, after: int) -> int:
        lines = self.source.splitlines()
        for i in range(after, len(lines)):
            stripped = lines[i].strip()
            if stripped and not stripped.startswith("#"):
                return i + 1
        return -1

    def annotations_on(self, line: int, kind: str) -> list:
        return [a for a in self.annotations if a.kind == kind and a.line == line]

    def annotation_in_range(self, lo: int, hi: int, kind: str) -> list:
        return [a for a in self.annotations if a.kind == kind and lo <= a.line <= hi]


@dataclass
class Project:
    """All modules under analysis; rules use it for cross-file checks."""

    modules: dict = field(default_factory=dict)  # name -> Module

    @classmethod
    def load(cls, paths: Iterable[Path], root: Optional[Path] = None) -> "Project":
        proj = cls()
        for p in sorted(set(paths)):
            mod = Module.load(p, root=root)
            proj.modules[mod.name] = mod
        return proj

    def find(self, suffix: str) -> Optional[Module]:
        """Find a module whose dotted name ends with ``suffix``.

        Matching is by whole dotted segments ("engine.rpc" matches
        "repro.engine.rpc" but not "repro.engine.grpc"), so rules work
        both on the real tree and on mutation-test temp copies whose
        top-level package name differs.
        """
        want = suffix.split(".")
        for name, mod in self.modules.items():
            if name.split(".")[-len(want):] == want:
                return mod
        return None


def collect_files(paths: Iterable[str]) -> list:
    """Expand files/dirs into a sorted list of .py files."""
    out = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return out


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


class Rule:
    """Base class: subclass, set ``rule_id``/``title``, implement a hook.

    ``check_module`` runs once per module; ``check_project`` once per
    run (for cross-file rules).  Either may yield/return Findings.
    """

    rule_id: str = "ODL???"
    title: str = ""
    rationale: str = ""  # one-liner pointing at the motivating bug/PR

    def check_module(self, module: Module, project: Project) -> Iterable[Finding]:
        return ()

    def check_project(self, project: Project) -> Iterable[Finding]:
        return ()


def run_rules(
    project: Project,
    rules: Iterable[Rule],
    with_suppression_findings: bool = True,
) -> list:
    """Run rules over a project, honoring per-line suppressions.

    Returns the surviving findings sorted by (path, line, rule).
    ODL000 findings (bare/malformed suppressions) are appended from the
    annotation parse and are themselves unsuppressable.
    """
    raw: list[Finding] = []
    rules = list(rules)
    for rule in rules:
        for mod in project.modules.values():
            raw.extend(rule.check_module(mod, project))
        raw.extend(rule.check_project(project))

    kept: list[Finding] = []
    for f in raw:
        mod = _module_for_path(project, f.path)
        if mod is not None and any(
            f.rule in d.rules and d.reason
            for d in mod.disables_for_line(f.line)
        ):
            continue
        kept.append(f)

    if with_suppression_findings:
        for mod in project.modules.values():
            kept.extend(mod.parse_findings)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept


def _module_for_path(project: Project, path: str) -> Optional[Module]:
    for mod in project.modules.values():
        if mod.path == path:
            return mod
    return None


# ---------------------------------------------------------------------------
# Baseline + reports
# ---------------------------------------------------------------------------


def load_baseline(path: Path) -> set:
    if not path.exists():
        return set()
    doc = json.loads(path.read_text() or "{}")
    return set(doc.get("fingerprints", []))


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    doc = {
        "comment": (
            "odlint baseline: fingerprints of accepted pre-existing findings. "
            "New findings not listed here fail CI."
        ),
        "fingerprints": sorted({f.fingerprint for f in findings}),
    }
    path.write_text(json.dumps(doc, indent=2) + "\n")


def apply_baseline(findings: Iterable[Finding], baseline: set) -> list:
    return [f for f in findings if f.fingerprint not in baseline]


def report_json(findings: Iterable[Finding], rules: Iterable[Rule]) -> str:
    doc = {
        "tool": "odlint",
        "rules": [
            {"id": r.rule_id, "title": r.title, "rationale": r.rationale}
            for r in rules
        ],
        "findings": [f.to_json() for f in findings],
        "count": len(list(findings)),
    }
    # recompute count defensively (findings may be a generator)
    doc["count"] = len(doc["findings"])
    return json.dumps(doc, indent=2)


def report_text(findings: Iterable[Finding]) -> str:
    findings = list(findings)
    if not findings:
        return "odlint: clean (0 findings)"
    lines = [f.format_text() for f in findings]
    lines.append(f"odlint: {len(findings)} finding(s)")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Small AST helpers shared by rules
# ---------------------------------------------------------------------------


def dotted(node: ast.AST) -> str:
    """Render Name/Attribute chains as 'a.b.c' ('' when not a chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(node: ast.Call) -> str:
    return dotted(node.func)


def str_const(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
