"""repro.distributed"""
