"""Logical-axis sharding: one place that maps tensor axes onto the mesh.

Models annotate tensors with *logical* axis names ("batch", "heads", ...);
this module resolves them to mesh axes via a rule table, so the same model
code runs on a single CPU device (rules inactive -> no-ops), the 16x16
single-pod mesh, and the 2x16x16 multi-pod mesh.

Default rule set (DESIGN.md §3):
  batch   -> ("pod", "data")     data parallel over pods x data axis
  heads/kv_heads/mlp/experts/vocab -> "model"   tensor/expert parallel
  seq_sp  -> "model"             sequence parallel (Megatron-SP regions)
  stream  -> ("fleet", "pod", "data")   ODL fleet heads; a dedicated
            1-D ``fleet`` mesh (launch.mesh.make_fleet_mesh) takes the
            whole axis, and on LLM meshes it rides the data axis

Use ``activate(mesh, rules)`` as a context manager; ``constrain`` is an
identity outside it.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "stream": ("fleet", "pod", "data"),
    "seq": None,
    "seq_sp": "model",  # sequence-parallel regions (hillclimb variant)
    "seq_kv": "model",  # decode KV/latent cache length (flash-decoding style)
    "seq_attn": None,  # q rows in attention (enabled when heads don't divide)
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_cap": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "elm_hidden": None,
    "elm_out": None,
    "classes": None,
    "layers": None,
    "frames": None,
}


def _current() -> tuple[Optional[Mesh], dict]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Optional[dict] = None):
    """Enable sharding constraints for model code under this mesh."""
    prev = _current()
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        with mesh:
            yield
    finally:
        _state.mesh, _state.rules = prev


@contextlib.contextmanager
def deactivate():
    """Temporarily disable sharding constraints inside an ``activate``
    scope.  For shard-*local* dispatch regions (e.g. a mesh-sharded
    stream session's per-shard plan/learn calls, each pinned to one
    device): under the enclosing mesh ``constrain`` would demand the
    full device set for single-device operands."""
    prev = _current()
    _state.mesh, _state.rules = None, DEFAULT_RULES
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def resolve(*logical_axes: Optional[str], shape: Optional[tuple] = None) -> P:
    """Logical axis names -> PartitionSpec under the active rules.

    Rules that name mesh axes absent from the active mesh degrade to
    replication (so the same model runs on a 2-axis or 3-axis mesh).  When
    ``shape`` is given, mesh axes that do not divide the dim are dropped
    (greedy prefix for multi-axis rules) — e.g. batch=1 stays replicated,
    56 heads on a 16-way model axis fall back to replication (and a schema
    post-pass reassigns 'model' to a divisible dim, see layers.param_specs).
    """
    mesh, rules = _current()
    axis_names = set(mesh.axis_names) if mesh is not None else set()
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}

    spec, used = [], set()
    for i, name in enumerate(logical_axes):
        if name is None:
            spec.append(None)
            continue
        target = rules.get(name, None)
        if target is None:
            spec.append(None)
            continue
        parts = (target,) if isinstance(target, str) else tuple(target)
        parts = tuple(p for p in parts if p in axis_names and p not in used)
        if shape is not None:
            dim = shape[i]
            kept, prod = [], 1
            for p in parts:  # greedy prefix that divides the dim
                if dim % (prod * mesh_shape[p]) == 0:
                    kept.append(p)
                    prod *= mesh_shape[p]
            parts = tuple(kept)
        used.update(parts)
        if not parts:
            spec.append(None)
        elif len(parts) == 1:
            spec.append(parts[0])
        else:
            spec.append(parts)
    return P(*spec)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; identity with no mesh."""
    mesh, _ = _current()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(*logical_axes, shape=x.shape))
    )


def named_sharding(
    *logical_axes: Optional[str], shape: Optional[tuple] = None
) -> Optional[NamedSharding]:
    mesh, _ = _current()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(*logical_axes, shape=shape))


def ensure_axis_sharded(spec: P, shape: tuple, axis: str) -> P:
    """Schema post-pass: add mesh axis `axis` to the largest divisible
    unsharded dim if the spec does not use it yet.

    Used twice on large params: (1) 'model' — memory safety for archs whose
    natural TP axis (e.g. 56 heads) does not divide the model axis; (2)
    'data' — FSDP/ZeRO-3 sharding of master params + moments, without which
    a 236B model's f32 state cannot fit 16 GB/chip on a 256-chip pod."""
    mesh, _ = _current()
    if mesh is None or axis not in mesh.axis_names:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    flat = []
    for e in entries:
        flat.extend(e if isinstance(e, tuple) else (e,))
    if axis in flat:
        return spec
    asize = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    cands = [
        (shape[i], i)
        for i, e in enumerate(entries)
        if e is None and shape[i] % asize == 0 and shape[i] >= asize
    ]
    if not cands:
        return spec
    _, idx = max(cands)
    entries[idx] = axis
    return P(*entries)


def ensure_model_sharded(spec: P, shape: tuple) -> P:
    return ensure_axis_sharded(spec, shape, "model")


def mesh_or_none() -> Optional[Mesh]:
    return _current()[0]


def fleet_axis_size() -> int:
    """Number of shards the ``stream`` rule resolves to under the active
    mesh: the product of the mesh-axis sizes that would split an (evenly
    divisible) fleet's leading axis.  1 with no mesh active — callers use
    this to size stream-axis padding before ``device_put``."""
    mesh, rules = _current()
    if mesh is None:
        return 1
    target = rules.get("stream", None)
    if target is None:
        return 1
    parts = (target,) if isinstance(target, str) else tuple(target)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for p in parts:
        n *= mesh_shape.get(p, 1)
    return n


def shard_map(f, mesh, in_specs, out_specs, check=False):
    """``jax.shard_map`` across jax versions: >= 0.5 exposes it top-level
    with ``check_vma``; 0.4.x has ``jax.experimental.shard_map.shard_map``
    with ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as sm_old

    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check)


def constrain_fleet(tree):
    """Constrain the leading axis of every leaf to the ``stream`` rule.

    The ODL fleet (``repro.engine.EngineState``) carries one head per stream
    on the leading axis of every leaf; under an active mesh this splits the
    fleet over ``("pod", "data")`` (per DEFAULT_RULES) with zero
    cross-stream communication.  Identity with no mesh active, and streams
    that don't divide the axis degrade to replication (see ``resolve``).
    """
    mesh, _ = _current()
    if mesh is None:
        return tree
    return jax.tree.map(
        lambda a: constrain(a, "stream", *((None,) * (a.ndim - 1))), tree
    )


def fleet_sharding(leaf_ndim: int, shape: Optional[tuple] = None) -> Optional[NamedSharding]:
    """NamedSharding placing a fleet leaf's leading axis on the stream rule
    (for explicit ``jax.device_put`` of an EngineState onto a mesh)."""
    return named_sharding("stream", *((None,) * (leaf_ndim - 1)), shape=shape)
