"""GPipe-style pipeline parallelism over a "stage" mesh axis (shard_map).

For depth-dominated configs (deepseek-coder-33b: 62 layers) pipeline stages
are an alternative to pure TP.  Layers are split into S stages; each stage's
params live on one slice of the ``stage`` axis; microbatches stream through
with ``jax.lax.ppermute`` moving activations stage->stage.  The classic
GPipe schedule runs S + M - 1 ticks for M microbatches (bubble fraction
(S-1)/(S+M-1)).

Register formulation: every stage holds one activation register.  At tick t,
stage s processes microbatch (t - s): stage 0 reads microbatch t from the
input stream, stages > 0 read the register filled by the upstream ppermute
of the previous tick, and the last stage publishes finished microbatches.
Per tick the collective cost is ONE collective-permute of a microbatch
activation (B_mb, S, d).

Exercised by tests/test_pipeline.py on a CPU subprocess mesh; available as a
dry-run variant for the hillclimb (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding


def pipeline_forward(
    h: jnp.ndarray,  # (M, B_mb, ...) microbatched activations (replicated)
    stage_params,  # pytree with leading (n_stages, ...) on every leaf
    stage_fn: Callable,  # (h_mb, params_one_stage) -> h_mb
    mesh: Mesh,
    axis: str = "stage",
) -> jnp.ndarray:
    """Run M microbatches through the pipeline; returns (M, B_mb, ...)."""
    n_stages = mesh.shape[axis]
    m = h.shape[0]
    ticks = n_stages + m - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(h_stream, params_local):
        # params_local arrives with a leading singleton stage dim — drop it.
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        last = n_stages - 1

        def tick(carry, t):
            reg, outputs = carry
            mb_idx = jnp.clip(t, 0, m - 1)
            inp = jnp.where(stage == 0, jnp.take(h_stream, mb_idx, axis=0), reg)
            y = stage_fn(inp, params_local)
            # Hand off to the next stage (ring; wraparound output is unused).
            reg_next = jax.lax.ppermute(y, axis, perm)
            # Last stage publishes microbatch t - last when in range.
            out_idx = jnp.clip(t - last, 0, m - 1)
            publish = (stage == last) & (t - last >= 0) & (t - last < m)
            updated = jax.lax.dynamic_update_index_in_dim(outputs, y, out_idx, 0)
            outputs = jnp.where(publish, updated, outputs)
            return (reg_next, outputs), None

        zeros = jnp.zeros_like(h_stream[0])
        outputs0 = jnp.zeros_like(h_stream)
        (_, outputs), _ = jax.lax.scan(tick, (zeros, outputs0), jnp.arange(ticks))
        # Only the last stage holds real outputs; psum replicates them.
        return jax.lax.psum(outputs * jnp.where(stage == last, 1.0, 0.0).astype(outputs.dtype), axis)

    return sharding.shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(), P(axis)),
        out_specs=P(),
        check=False,
    )(h, stage_params)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead: (S - 1) / (S + M - 1)."""
    return (n_stages - 1) / (n_stages + n_microbatches - 1)


def sequential_reference(h, stage_params, stage_fn, n_stages: int):
    """Apply all stages in order to every microbatch (the test oracle)."""
    out = []
    for mb in range(h.shape[0]):
        x = h[mb]
        for s in range(n_stages):
            params_s = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(x, params_s)
        out.append(x)
    return jnp.stack(out)
