"""repro.optim"""
