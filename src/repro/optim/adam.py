"""AdamW from scratch (no optax in this container) + ZeRO-1 spec helper."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamState(NamedTuple):
    step: jnp.ndarray  # () int32
    m: dict  # first-moment pytree (f32, ZeRO-1 sharded)
    v: dict  # second-moment pytree


def init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def update(
    grads, state: AdamState, params, cfg: TrainConfig, lr_scale: jnp.ndarray | float = 1.0
):
    """AdamW step; returns (new_params, new_state).  Global-norm clipping."""
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.learning_rate * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        p2 = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + 1e-8) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamState(step=step, m=new_m, v=new_v), gnorm


def zero1_axes(axes: tuple, shape: tuple, data_divisor: int) -> tuple:
    """ZeRO-1: extend a param's logical axes for its optimizer moments by
    sharding the first replicated-and-divisible dim over the data axis.

    E.g. a TP-sharded (d, ff) weight with axes ('embed', 'mlp') -> moments
    axes ('zero1', 'mlp'), halving optimizer-state HBM per data shard.
    """
    out = list(axes)
    for i, (ax, dim) in enumerate(zip(axes, shape)):
        if ax in (None, "embed", "head_dim", "expert_cap") and dim % data_divisor == 0 and dim >= data_divisor:
            out[i] = "zero1"
            break
    return tuple(out)
