"""Synthetic surrogate of the UCI-HAR dataset (paper [1]) with subject drift.

The real dataset is not redistributable inside this offline container
(DESIGN.md §5).  This generator mirrors its published structure:

  * 30 human subjects, 6 classes (Walking, WalkUp, WalkDown, Sitting,
    Standing, Laying), 561-dim feature vectors in [-1, 1];
  * samples cluster per (subject, class) — Fig. 1 of the paper shows strong
    per-subject clustering for Walking/WalkUp/WalkDown/Laying, weaker for
    Sitting/Standing;
  * ~10k samples total, ~70/30 train/test split per subject;
  * high sample redundancy within a (subject, class) cluster (the property
    that makes data pruning effective — paper §3.2).

Drift protocol (paper §3): subjects {9, 14, 16, 19, 25} are held out of
train/test0 and form test1.  The held-out subjects get the largest subject
offsets so the shift is material (NoODL drops ~10 accuracy points).
"""

from __future__ import annotations

import dataclasses

import numpy as np

N_SUBJECTS = 30
N_CLASSES = 6
N_FEATURES = 561
DRIFT_SUBJECTS = (9, 14, 16, 19, 25)
CLASS_NAMES = ("Walking", "WalkUp", "WalkDown", "Sitting", "Standing", "Laying")


@dataclasses.dataclass
class HARSplits:
    train_x: np.ndarray
    train_y: np.ndarray
    test0_x: np.ndarray
    test0_y: np.ndarray
    test1_x: np.ndarray
    test1_y: np.ndarray


def _subject_scale(subject_rng: np.random.Generator, drifted: bool) -> float:
    # Held-out subjects sit farther from the population mean (paper Fig. 1:
    # the removed subjects form distinguishable clusters).  1.45 calibrated so
    # NoODL(N=128) lands on the paper's 82.9 % post-drift accuracy (Table 3).
    return 1.45 if drifted else 1.0


def generate(
    seed: int = 0,
    samples_per_subject_class: int = 56,
    subject_sigma: float = 0.17,
    class_sep: float = 0.13,
    noise_sigma: float = 0.35,
    hard_frac: float = 0.15,
    hard_scale: float = 1.8,
) -> HARSplits:
    """Build the drifted HAR surrogate.

    x[s, c, i] = tanh( mu_class[c] + scale_s * delta_subject[s, c] + sigma_i * eps_i )

    Per-sample noise ``sigma_i`` is bimodal: a ``1 - hard_frac`` majority of
    near-duplicate "cluster core" samples (continuous sensor streams are
    highly redundant — paper §3.2) plus a ``hard_frac`` minority of boundary
    samples with ``hard_scale``x the noise.  This is what makes confidence
    well-calibrated and P1P2 pruning effective: core samples are
    high-confidence/high-accuracy, boundary samples low-confidence.
    """
    rng = np.random.default_rng(seed)
    # Class prototypes: drawn sparse-ish so classes are linearly separable.
    mu = rng.normal(0.0, class_sep, size=(N_CLASSES, N_FEATURES))
    # Static-posture classes (Sitting/Standing) are closer together (Fig. 1).
    mu[4] = mu[3] + rng.normal(0.0, 0.35 * class_sep, size=N_FEATURES)

    xs, ys, subs = [], [], []
    for s in range(N_SUBJECTS):
        srng = np.random.default_rng(seed * 1009 + 7 * s + 1)
        drifted = s in DRIFT_SUBJECTS
        scale = _subject_scale(srng, drifted)
        # Per-(subject, class) offset — the clusters of Fig. 1.
        delta = srng.normal(0.0, subject_sigma, size=(N_CLASSES, N_FEATURES))
        for c in range(N_CLASSES):
            center = mu[c] + scale * delta[c]
            k = samples_per_subject_class
            eps = srng.normal(0.0, noise_sigma, size=(k, N_FEATURES))
            hard = (srng.uniform(size=k) < hard_frac).astype(np.float64)
            sigma = (0.35 + hard * (hard_scale - 0.35))[:, None]
            x = np.tanh(center[None, :] + sigma * eps)
            xs.append(x)
            ys.append(np.full(k, c, dtype=np.int32))
            subs.append(np.full(k, s, dtype=np.int32))

    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys)
    sub = np.concatenate(subs)

    # Shuffle globally, then split.
    perm = rng.permutation(len(x))
    x, y, sub = x[perm], y[perm], sub[perm]

    drift_mask = np.isin(sub, DRIFT_SUBJECTS)
    keep_x, keep_y = x[~drift_mask], y[~drift_mask]
    test1_x, test1_y = x[drift_mask], y[drift_mask]

    # 70/30 train/test0 split of the kept subjects (paper reuses the dataset's
    # original split; exact fractions are immaterial to the protocol).
    n_train = int(0.7 * len(keep_x))
    return HARSplits(
        train_x=keep_x[:n_train],
        train_y=keep_y[:n_train],
        test0_x=keep_x[n_train:],
        test0_y=keep_y[n_train:],
        test1_x=test1_x,
        test1_y=test1_y,
    )


def odl_split(splits: HARSplits, frac: float = 0.6, seed: int = 0, bout_len: int = 70):
    """Paper §3 steps 3-4: ~60% of test1 for ODL retraining, rest for test.

    The retraining portion is arranged as a *temporally coherent stream*:
    contiguous bouts of ~``bout_len`` same-class samples (a person walks for a
    while, then sits for a while, ...), which is how the smartphone dataset is
    actually recorded.  Bout structure is what makes consecutive-success
    streaks (the auto-theta X=10 rule) attainable on real sensor streams.
    The held-out test portion stays i.i.d.-shuffled.
    """
    rng = np.random.default_rng(seed + 12345)
    n = len(splits.test1_x)
    perm = rng.permutation(n)
    k = int(frac * n)
    tr, te = perm[:k], perm[k:]
    tx, ty = splits.test1_x[tr], splits.test1_y[tr]

    # Group the training portion by class, then emit random-order bouts.
    by_class = [np.where(ty == c)[0] for c in range(N_CLASSES)]
    for idxs in by_class:
        rng.shuffle(idxs)
    cursors = [0] * N_CLASSES
    order = []
    while any(cursors[c] < len(by_class[c]) for c in range(N_CLASSES)):
        avail = [c for c in range(N_CLASSES) if cursors[c] < len(by_class[c])]
        c = int(rng.choice(avail))
        L = int(rng.integers(bout_len // 2, bout_len * 3 // 2 + 1))
        take = by_class[c][cursors[c] : cursors[c] + L]
        cursors[c] += len(take)
        order.extend(take.tolist())
    order = np.asarray(order, dtype=np.int64)

    return tx[order], ty[order], splits.test1_x[te], splits.test1_y[te]


def drift_tick_stream(
    splits: HARSplits,
    n_streams: int = 1,
    frac: float = 0.6,
    seed: int = 0,
    bout_len: int = 70,
    calm: int = 0,
    severities=None,
):
    """Tick-iterator view of the drifted ODL stream for the streaming
    runtime (``repro.engine.stream.run``): one ``(S, n_in)`` float32 tick at
    a time, never materializing the full ``(T, S, n_in)`` array.

    The stream is an optional ``calm``-tick prefix of known-subject (test0)
    data followed by the §3 retraining stream of the held-out subjects,
    with a per-stream drift ``severities`` multiplier applied at shift time
    (``x -> clip(x * sev + 0.4 * sev, -3, 3)`` — S users hitting the same
    drift at different strengths).  Defaults to severity 1.0 (no extra
    scaling) for every stream.

    Returns ``(ticks, labels)``: ``ticks`` is a generator of (S, n_in)
    ticks and ``labels`` the matching (T, S) int32 ground-truth array for
    the teacher side (labels are 1 byte/tick/stream — the paper's protocol
    has ground truth play the teacher; it is the features that must not
    materialize).
    """
    ox, oy, _, _ = odl_split(splits, frac, seed, bout_len)
    if severities is None:
        severities = np.ones(n_streams, np.float32)
    severities = np.asarray(severities, np.float32)
    if severities.shape != (n_streams,):
        raise ValueError(f"severities must be ({n_streams},), got {severities.shape}")
    calm_x, calm_y = splits.test0_x[:calm], splits.test0_y[:calm]
    if len(calm_x) < calm:
        raise ValueError(f"calm prefix {calm} exceeds test0 size {len(splits.test0_x)}")
    labels = np.concatenate([calm_y, oy]).astype(np.int32)
    labels = np.broadcast_to(labels[:, None], (len(labels), n_streams))

    def ticks():
        for row in calm_x:
            yield np.broadcast_to(row, (n_streams, N_FEATURES)).astype(np.float32)
        scale = severities[:, None]
        for row in ox:
            yield np.clip(row[None, :] * scale + 0.4 * scale, -3, 3).astype(np.float32)

    return ticks(), labels
