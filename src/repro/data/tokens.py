"""Synthetic sharded token-stream pipeline for LM training.

Deterministic, seekable, host-shardable: batch i of host h is a pure
function of (seed, step, host) — the property that makes checkpoint/restart
exact (restore step -> identical remaining stream) and lets every host of a
pod produce only its slice without coordination.

The stream is a Zipf-ish unigram mix with short-range repetition structure
(so losses fall during the example runs rather than sitting at ln V), plus
per-sequence ODL "domain" labels (the teacher labels the paper's head
trains on).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_domains: int = 6  # ODL head classes
    seed: int = 0
    n_hosts: int = 1
    host: int = 0


def _domain_unigram(rng: np.random.Generator, vocab: int, n_domains: int):
    """Per-domain Zipf unigram distributions over disjoint-ish preferred sets."""
    base = 1.0 / (np.arange(1, vocab + 1) ** 1.1)
    tables = []
    for d in range(n_domains):
        perm = np.random.default_rng(1000 + d).permutation(vocab)
        p = base[perm]
        tables.append(p / p.sum())
    return np.stack(tables)


class TokenStream:
    def __init__(self, cfg: TokenStreamConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self._tables = _domain_unigram(
            np.random.default_rng(cfg.seed), cfg.vocab_size, cfg.n_domains
        )

    def batch(self, step: int) -> dict:
        """Batch for (step, host): tokens/labels (B_local, S), odl_labels (B_local,)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 64 + cfg.host
        )
        domains = rng.integers(0, cfg.n_domains, size=self.local_batch)
        toks = np.empty((self.local_batch, cfg.seq_len + 1), np.int32)
        for i, d in enumerate(domains):
            seq = rng.choice(cfg.vocab_size, size=cfg.seq_len + 1, p=self._tables[d])
            # Short-range repetition: with p=0.3, copy the token 4 back.
            rep = rng.uniform(size=cfg.seq_len + 1) < 0.3
            rep[:4] = False
            idx = np.arange(cfg.seq_len + 1)
            seq[rep] = seq[idx[rep] - 4]
            toks[i] = seq
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
            "odl_labels": domains.astype(np.int32),
        }
