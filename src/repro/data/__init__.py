"""Data pipelines: synthetic HAR surrogate + sharded token streams."""
