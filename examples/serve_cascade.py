"""Cascade serving: the paper's data pruning as a serving-cost saver.

Setup: B streams decode concurrently; each stream belongs to a latent
domain (its token distribution).  The per-stream OS-ELM heads learn to
classify the domain from backbone features, online, from teacher labels.
The P1P2 gate + auto-theta decides per tick which streams still need the
teacher — as heads converge, teacher traffic collapses, exactly the
communication-volume curve of paper Fig. 3 transplanted into an LLM-serving
cascade.

Run:  PYTHONPATH=src python examples/serve_cascade.py
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.models import model as model_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ticks", type=int, default=120)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, "smoke")
    key = jax.random.PRNGKey(0)
    params = model_lib.layers.init_params(model_lib.build_schema(cfg), key)

    # Domain-coherent streams: stream s draws tokens from domain s % n_out;
    # precompute each domain's 100 most likely token ids once.
    n_dom = cfg.odl.n_out
    domains = np.arange(args.batch) % n_dom
    tables = TokenStream(
        TokenStreamConfig(cfg.vocab_size, 1, 1, n_domains=n_dom)
    )._tables
    top_ids = np.argsort(tables, axis=1)[:, -100:]  # (n_dom, 100)

    state = model_lib.init_serve_state(cfg, args.batch, max_len=args.ticks + 4)
    step = jax.jit(lambda p, st, t: model_lib.serve_step(p, st, t, cfg))
    apply_lbl = jax.jit(
        lambda st, ctx, l, m: model_lib.serve_apply_labels(st, ctx, l, m, cfg)
    )

    labels = jnp.asarray(domains, jnp.int32)  # teacher's answer = true domain
    window = []
    for t in range(args.ticks):
        tok = np.stack(
            [top_ids[d, (t + i) % 100] for i, d in enumerate(domains)]
        ).astype(np.int32)[:, None]
        logits, state, odl = step(params, state, jnp.asarray(tok))
        q = odl.queried
        # Teacher answers this tick's queries (synchronously, for clarity);
        # the GateOutput carries the query-time context the answer is
        # judged against.
        state = apply_lbl(state, odl, labels, q)
        window.append(float(jnp.mean(q.astype(jnp.float32))))
        if (t + 1) % 20 == 0:
            frac = np.mean(window[-20:])
            print(f"tick {t+1:4d}: teacher query fraction (last 20) = {frac:.2f}")

    early, late = np.mean(window[:20]), np.mean(window[-20:])
    print(f"\nteacher traffic: first 20 ticks {early:.2f} -> last 20 ticks {late:.2f}")
    print("the P1P2/auto-theta gate prunes teacher calls as the fleet adapts"
          if late < early else "heads still warming up — raise --ticks")


if __name__ == "__main__":
    main()
