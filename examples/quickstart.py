"""Quickstart: the paper's tiny supervised ODL core in ~40 lines.

Trains an ODLHash core (n=561, N=128, m=6) on the HAR surrogate, hits it
with the subject drift, retrains online with auto data pruning, and prints
the accuracy recovery + communication saving (paper Fig. 3 'Auto').

The whole loop runs on ``repro.engine`` — the same batched state machine
that serves thousands of streams — here as a fleet of exactly one.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import oselm, pruning
from repro.data import har


def main():
    data = har.generate(seed=0)

    elm = oselm.OSELMConfig(n_in=561, n_hidden=128, n_out=6, variant="hash")
    cfg = engine.EngineConfig(elm=elm, prune=pruning.PruneConfig.for_hidden(128))

    # Initial training (paper §3 step 1): classic OS-ELM batch boot, then
    # broadcast to a one-stream fleet.
    core = engine.init_state(cfg)._replace(
        elm=oselm.init_state_batch(
            elm, jnp.asarray(data.train_x), jax.nn.one_hot(data.train_y, 6)
        )
    )
    fleet = engine.broadcast_streams(core, 1)
    acc = lambda st, x, y: float(
        engine.fleet_accuracy(st, jnp.asarray(x), jnp.asarray(y), cfg)[0]
    )
    print(f"before drift (test0): {100*acc(fleet, data.test0_x, data.test0_y):.1f}%")

    # Drift: five held-out subjects (paper §3 steps 3-4).
    ox, oy, tx, ty = har.odl_split(data, frac=0.6, seed=0)
    print(f"after drift, NO ODL : {100*acc(fleet, tx, ty):.1f}%")

    # Supervised ODL with auto data pruning over the drifted stream: re-arm
    # the pruning phase counter, then scan the retraining phase.
    fleet = fleet._replace(prune=pruning.reset_phase(fleet.prune))
    fleet, outs = engine.run_fleet(
        fleet, jnp.asarray(ox)[:, None], jnp.asarray(oy, jnp.int32)[:, None],
        cfg, mode="train_phase",
    )
    head = engine.stream_slice(fleet, 0)
    comm = float(pruning.comm_volume_fraction(head.prune))
    print(f"after drift, ODL    : {100*acc(fleet, tx, ty):.1f}%")
    print(f"teacher queries     : {int(head.prune.queries)}/{len(ox)} "
          f"({100*comm:.1f}% comm volume, {100*(1-comm):.1f}% saved)")
    print(f"bytes to teacher    : {int(head.meter.up_bytes):,} "
          f"(saved {int((1/comm - 1) * head.meter.up_bytes):,})")
    print(f"final auto-theta    : {float(pruning.theta_of(head.prune, cfg.prune)):.2f}")


if __name__ == "__main__":
    main()
