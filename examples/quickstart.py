"""Quickstart: the paper's tiny supervised ODL core in ~40 lines.

Trains an ODLHash core (n=561, N=128, m=6) on the HAR surrogate, hits it
with the subject drift, retrains online with auto data pruning, and prints
the accuracy recovery + communication saving (paper Fig. 3 'Auto').

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import functools

import jax
import jax.numpy as jnp

from repro.core import odl_head, oselm, pruning
from repro.data import har


def main():
    data = har.generate(seed=0)

    elm = oselm.OSELMConfig(n_in=561, n_hidden=128, n_out=6, variant="hash")
    cfg = odl_head.ODLCoreConfig(elm=elm, prune=pruning.PruneConfig.for_hidden(128))

    # Initial training (paper §3 step 1): classic OS-ELM batch boot.
    core = odl_head.init_state(cfg)._replace(
        elm=oselm.init_state_batch(
            elm, jnp.asarray(data.train_x), jax.nn.one_hot(data.train_y, 6)
        )
    )
    acc = lambda c, x, y: float(
        odl_head.accuracy(c, jnp.asarray(x), jnp.asarray(y), cfg)
    )
    print(f"before drift (test0): {100*acc(core, data.test0_x, data.test0_y):.1f}%")

    # Drift: five held-out subjects (paper §3 steps 3-4).
    ox, oy, tx, ty = har.odl_split(data, frac=0.6, seed=0)
    print(f"after drift, NO ODL : {100*acc(core, tx, ty):.1f}%")

    # Supervised ODL with auto data pruning over the drifted stream.
    core, outs = jax.jit(functools.partial(odl_head.run_training_phase, cfg=cfg))(
        core, jnp.asarray(ox), jnp.asarray(oy)
    )
    comm = float(pruning.comm_volume_fraction(core.prune))
    print(f"after drift, ODL    : {100*acc(core, tx, ty):.1f}%")
    print(f"teacher queries     : {int(core.prune.queries)}/{len(ox)} "
          f"({100*comm:.1f}% comm volume, {100*(1-comm):.1f}% saved)")
    print(f"bytes to teacher    : {int(core.meter.up_bytes):,} "
          f"(saved {int((1/comm - 1) * core.meter.up_bytes):,})")
    print(f"final auto-theta    : {float(pruning.theta_of(core.prune, cfg.prune)):.2f}")


if __name__ == "__main__":
    main()
