"""End-to-end driver: train an LM with the fused ODL head (deliverable (b)).

Presets:
  smoke (default) — 2-layer qwen3-family, ~1 M params, 50 steps, <1 min CPU.
  100m            — 12 x d768 qwen3-family (~124 M params incl. embeddings),
                    300 steps at batch 8 x seq 128 — the "train a ~100M model
                    for a few hundred steps" configuration (hours on CPU;
                    the loop itself is the same one the dry-run proves on
                    the 256-chip mesh).

The train step fuses the paper's technique: every step the OS-ELM head
RLS-trains on pooled hidden features, with P1P2 auto-pruning deciding which
rows may skip their teacher label.  Watch odl_q (query fraction) fall as
theta relaxes — the paper's Fig. 3 happening inside an LM training loop.

Run:  PYTHONPATH=src python examples/train_lm_odl.py [--preset 100m]
"""

import argparse

from repro import configs
from repro.launch.train import train


def preset_cfg(preset: str):
    if preset == "smoke":
        return dict(steps=50, batch=8, seq=64, arch_override=None)
    if preset == "100m":
        arch = configs.get_config("qwen3-4b", "smoke").replace(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=2048, vocab_size=32_000,
        )
        return dict(steps=300, batch=8, seq=128, arch_override=arch)
    raise ValueError(preset)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_odl_ckpt")
    args = ap.parse_args(argv)

    p = preset_cfg(args.preset)
    steps = args.steps or p["steps"]

    if p["arch_override"] is not None:
        # Register the override through a tiny monkey-patched getter.
        import repro.configs as C

        orig = C.get_config
        C.get_config = lambda a, v="full": (
            p["arch_override"] if a == "qwen3-4b" else orig(a, v)
        )

    from repro.models import layers, model as model_lib

    cfg = configs.get_config("qwen3-4b", "smoke")
    n_params = layers.count_params(model_lib.build_schema(cfg))
    print(f"preset={args.preset}: {n_params:,} params, {steps} steps")
    _, losses = train(
        "qwen3-4b", "smoke", steps=steps, batch=p["batch"], seq=p["seq"],
        ckpt_dir=args.ckpt_dir, ckpt_every=50,
    )
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'flat'})")


if __name__ == "__main__":
    main()
