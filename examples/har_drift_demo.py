"""Full Algorithm-1 demo on the streaming runtime: ticks arrive one at a
time, teacher answers arrive late.

A sensor stream starts with known-subject data (predicting mode), then the
distribution shifts to the held-out subjects.  The core detects the drift,
enters training mode, acquires labels through the auto-pruned teacher
channel — here an *asynchronous* teacher with real latency — converges,
and drops back to predicting mode: the complete loop of the paper's
Fig. 2/Algorithm 1, plus the Fig. 4 power accounting.

Part two scales the same loop to a fleet: S users hit the drift at
different severities and a laggy, jittery teacher answers out of order
while ``repro.engine.stream.run`` keeps every stream's detector/pruner/
head moving (this is the path the serving cascade uses at thousands of
streams); ``engine.run_fleet`` runs the same ticks as one fused offline
scan for the throughput comparison.

Run:  PYTHONPATH=src python examples/har_drift_demo.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import drift, oselm, power_model, pruning
from repro.data import har
from repro.engine import stream

CALM = 400


def main():
    data = har.generate(seed=0)
    elm = oselm.OSELMConfig(n_in=561, n_hidden=128, n_out=6, variant="hash")
    cfg = engine.EngineConfig(
        elm=elm,
        prune=pruning.PruneConfig.for_hidden(128),
        drift=drift.DriftConfig(warmup=48, k_sigma=3.0, enter_hits=2, exit_calm=64),
    )
    core = engine.init_state(cfg)._replace(
        elm=oselm.init_state_batch(
            elm, jnp.asarray(data.train_x), jax.nn.one_hot(data.train_y, 6)
        )
    )

    # ---- Part one: one stream, zero-latency teacher (the paper's loop). ---
    ticks, labels = har.drift_tick_stream(
        data, n_streams=1, seed=0, calm=CALM, severities=[2.0]
    )
    t_total = len(labels)
    teacher = stream.LatencyTeacher(stream.array_labels(labels), latency=0)
    st, outs, _ = stream.run(
        engine.broadcast_streams(core, 1), ticks, cfg, teacher, mode="algo1"
    )

    training = outs.mode_training[:, 0]
    queried = outs.queried[:, 0]
    first_train = int(training.argmax()) if training.any() else -1
    print(f"stream length          : {t_total} samples (shift at {CALM})")
    print(f"drift detected at      : sample {first_train}")
    print(f"training-mode samples  : {int(training.sum())}")
    print(f"teacher queries        : {int(queried.sum())} "
          f"({100*queried.sum()/max(training.sum(),1):.1f}% of training mode)")

    # Fig. 4-style power accounting at one event per second.
    comm = float(queried.sum() / max(training.sum(), 1))
    for period in (1.0, 5.0, 10.0):
        mw = power_model.avg_power_mw(comm, period)
        red = power_model.power_reduction_pct(comm, period)
        print(f"power @ 1 ev/{period:>4.0f}s     : {mw:6.3f} mW "
              f"({red:4.1f}% saved vs no pruning)")

    # ---- Part two: fleet of S streams, laggy out-of-order teacher. --------
    n_streams = 8
    severities = np.linspace(1.2, 2.6, n_streams)
    ticks, labels = har.drift_tick_stream(
        data, n_streams=n_streams, seed=0, calm=CALM, severities=severities
    )
    fstate0 = engine.broadcast_streams(core, n_streams)
    lag_teacher = stream.LatencyTeacher(
        stream.array_labels(labels), latency=3, jitter=4, seed=1
    )
    fstate, fouts, stats = stream.run(
        fstate0, ticks, cfg, lag_teacher, mode="algo1", capacity=32
    )

    print(f"\nfleet of {n_streams} streams    : {stats.steps_per_s:,.0f} stream-steps/s "
          f"(streaming, teacher latency 3+U[0,4] ticks)")
    print(f"tick latency           : p50 {stats.tick_p50_ms:.2f} ms, "
          f"p95 {stats.tick_p95_ms:.2f} ms")
    print(f"label latency          : p50 {stats.label_latency_p50:.0f} ticks, "
          f"p95 {stats.label_latency_p95:.0f} ticks; "
          f"{stats.labels_applied}/{stats.queries_issued} queries answered, "
          f"{stats.tickets_dropped} tickets dropped")
    for s in range(n_streams):
        tr = fouts.mode_training[:, s]
        det = int(tr.argmax()) if tr.any() else -1
        prune_s = jax.tree.map(lambda a: a[s], fstate.prune)
        print(f"  stream {s} (x{severities[s]:.1f} shift): drift at {det:4d}, "
              f"queries {int(fstate.prune.queries[s]):4d}, "
              f"comm {float(pruning.comm_volume_fraction(prune_s)):.2f}")

    # Offline comparison: the same ticks as one fused, chunked scan.
    ticks2, labels2 = har.drift_tick_stream(
        data, n_streams=n_streams, seed=0, calm=CALM, severities=severities
    )
    fleet_xs = jnp.asarray(np.stack(list(ticks2)))
    fleet_ys = jnp.asarray(labels2)
    # Fresh state per run_fleet call: off-CPU, run_fleet donates its input
    # buffers, so the warmup must not consume the timed call's state.
    jax.block_until_ready(
        engine.run_fleet(engine.broadcast_streams(core, n_streams),
                         fleet_xs[:256], fleet_ys[:256], cfg,
                         mode="algo1", chunk=256)[0].elm.beta
    )
    t0 = time.perf_counter()
    off_state, _ = engine.run_fleet(
        engine.broadcast_streams(core, n_streams), fleet_xs, fleet_ys, cfg,
        mode="algo1", chunk=256
    )
    jax.block_until_ready(off_state.elm.beta)
    dt = time.perf_counter() - t0
    print(f"\noffline run_fleet      : {fleet_xs.shape[0] * n_streams / dt:,.0f} "
          f"stream-steps/s (one fused scan, chunk=256)")


if __name__ == "__main__":
    main()
