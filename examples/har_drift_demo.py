"""Full Algorithm-1 demo: the drift detector switches modes on its own.

A sensor stream starts with known-subject data (predicting mode), then the
distribution shifts to the held-out subjects.  The core detects the drift,
enters training mode, acquires labels through the auto-pruned teacher
channel, converges, and drops back to predicting mode — the complete loop
of the paper's Fig. 2/Algorithm 1, plus the Fig. 4 power accounting.

Part two scales the same loop to a fleet: S users hit the drift at
different severities, and ``repro.engine.run_fleet`` runs every stream's
detector/pruner/head in one fused scan (this is the path the serving
cascade uses at thousands of streams).

Run:  PYTHONPATH=src python examples/har_drift_demo.py
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import drift, odl_head, oselm, power_model, pruning
from repro.data import har


def main():
    data = har.generate(seed=0)
    elm = oselm.OSELMConfig(n_in=561, n_hidden=128, n_out=6, variant="hash")
    cfg = odl_head.ODLCoreConfig(
        elm=elm,
        prune=pruning.PruneConfig.for_hidden(128),
        drift=drift.DriftConfig(warmup=48, k_sigma=3.0, enter_hits=2, exit_calm=64),
    )
    core = odl_head.init_state(cfg)._replace(
        elm=oselm.init_state_batch(
            elm, jnp.asarray(data.train_x), jax.nn.one_hot(data.train_y, 6)
        )
    )

    # Stream: calm known-subject segment, then a hard shift (scaled features).
    calm_x, calm_y = data.test0_x[:400], data.test0_y[:400]
    ox, oy, tx, ty = har.odl_split(data, 0.6, seed=0)
    shift_x = np.clip(ox * 2.0 + 0.8, -3, 3)
    xs = jnp.asarray(np.concatenate([calm_x, shift_x]))
    ys = jnp.asarray(np.concatenate([calm_y, oy]).astype(np.int32))

    core2, outs = jax.jit(functools.partial(odl_head.run_stream, cfg=cfg))(core, xs, ys)

    training = np.asarray(outs.mode_training)
    queried = np.asarray(outs.queried)
    first_train = int(training.argmax()) if training.any() else -1
    print(f"stream length          : {len(xs)} samples (shift at {len(calm_x)})")
    print(f"drift detected at      : sample {first_train}")
    print(f"training-mode samples  : {int(training.sum())}")
    print(f"teacher queries        : {int(queried.sum())} "
          f"({100*queried.sum()/max(training.sum(),1):.1f}% of training mode)")

    # Fig. 4-style power accounting at one event per second.
    comm = float(queried.sum() / max(training.sum(), 1))
    for period in (1.0, 5.0, 10.0):
        mw = power_model.avg_power_mw(comm, period)
        red = power_model.power_reduction_pct(comm, period)
        print(f"power @ 1 ev/{period:>4.0f}s     : {mw:6.3f} mW "
              f"({red:4.1f}% saved vs no pruning)")

    # ---- Fleet mode: S users, drift severity varies per user. -------------
    n_streams = 8
    severities = np.linspace(1.2, 2.6, n_streams)
    fleet_xs = np.stack(
        [
            np.concatenate([calm_x, np.clip(ox * s + 0.4 * s, -3, 3)])
            for s in severities
        ],
        axis=1,
    )  # (T, S, n_in)
    fleet_ys = np.broadcast_to(np.asarray(ys)[:, None], fleet_xs.shape[:2])
    fstate = engine.broadcast_streams(core, n_streams)
    fleet_xs, fleet_ys = jnp.asarray(fleet_xs), jnp.asarray(fleet_ys)

    # Warm up the chunk executable so the throughput line measures the scan,
    # not jit compilation.
    jax.block_until_ready(
        engine.run_fleet(fstate, fleet_xs[:256], fleet_ys[:256], cfg,
                         mode="algo1", chunk=256)[0].elm.beta
    )
    t0 = time.perf_counter()
    fstate, fouts = engine.run_fleet(
        fstate, fleet_xs, fleet_ys, cfg, mode="algo1", chunk=256,
    )
    jax.block_until_ready(fstate.elm.beta)
    dt = time.perf_counter() - t0
    sps = fleet_xs.shape[0] * n_streams / dt

    print(f"\nfleet of {n_streams} streams   : {sps:,.0f} stream-steps/s "
          f"(one fused scan, chunk=256)")
    ftraining = np.asarray(fouts.mode_training)
    for s in range(n_streams):
        det = int(ftraining[:, s].argmax()) if ftraining[:, s].any() else -1
        print(f"  stream {s} (x{severities[s]:.1f} shift): drift at {det:4d}, "
              f"queries {int(fstate.prune.queries[s]):4d}, "
              f"comm {float(pruning.comm_volume_fraction(jax.tree.map(lambda a: a[s], fstate.prune))):.2f}")


if __name__ == "__main__":
    main()
