"""Full Algorithm-1 demo: the drift detector switches modes on its own.

A sensor stream starts with known-subject data (predicting mode), then the
distribution shifts to the held-out subjects.  The core detects the drift,
enters training mode, acquires labels through the auto-pruned teacher
channel, converges, and drops back to predicting mode — the complete loop
of the paper's Fig. 2/Algorithm 1, plus the Fig. 4 power accounting.

Run:  PYTHONPATH=src python examples/har_drift_demo.py
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import drift, odl_head, oselm, power_model, pruning
from repro.data import har


def main():
    data = har.generate(seed=0)
    elm = oselm.OSELMConfig(n_in=561, n_hidden=128, n_out=6, variant="hash")
    cfg = odl_head.ODLCoreConfig(
        elm=elm,
        prune=pruning.PruneConfig.for_hidden(128),
        drift=drift.DriftConfig(warmup=48, k_sigma=3.0, enter_hits=2, exit_calm=64),
    )
    core = odl_head.init_state(cfg)._replace(
        elm=oselm.init_state_batch(
            elm, jnp.asarray(data.train_x), jax.nn.one_hot(data.train_y, 6)
        )
    )

    # Stream: calm known-subject segment, then a hard shift (scaled features).
    calm_x, calm_y = data.test0_x[:400], data.test0_y[:400]
    ox, oy, tx, ty = har.odl_split(data, 0.6, seed=0)
    shift_x = np.clip(ox * 2.0 + 0.8, -3, 3)
    xs = jnp.asarray(np.concatenate([calm_x, shift_x]))
    ys = jnp.asarray(np.concatenate([calm_y, oy]).astype(np.int32))

    core, outs = jax.jit(functools.partial(odl_head.run_stream, cfg=cfg))(core, xs, ys)

    training = np.asarray(outs.mode_training)
    queried = np.asarray(outs.queried)
    first_train = int(training.argmax()) if training.any() else -1
    print(f"stream length          : {len(xs)} samples (shift at {len(calm_x)})")
    print(f"drift detected at      : sample {first_train}")
    print(f"training-mode samples  : {int(training.sum())}")
    print(f"teacher queries        : {int(queried.sum())} "
          f"({100*queried.sum()/max(training.sum(),1):.1f}% of training mode)")

    # Fig. 4-style power accounting at one event per second.
    comm = float(queried.sum() / max(training.sum(), 1))
    for period in (1.0, 5.0, 10.0):
        mw = power_model.avg_power_mw(comm, period)
        red = power_model.power_reduction_pct(comm, period)
        print(f"power @ 1 ev/{period:>4.0f}s     : {mw:6.3f} mW "
              f"({red:4.1f}% saved vs no pruning)")


if __name__ == "__main__":
    main()
